"""Figure 2 reproduction: in-situ substructure ("galaxy") finding.

The paper clusters stellar particles with DBSCAN minPts=10 inside the
largest dark-matter halo and draws a circle per galaxy (radius = farthest
member from the centroid). Same analysis here on the synthetic benchmark
cloud; prints per-galaxy radii + membership (the data behind the figure).

  PYTHONPATH=src python examples/galaxy_finding.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.dbscan import fdbscan
from repro.data.pipeline import hacc_benchmark_epsilon, make_clustered_points

n = 1536
pts = make_clustered_points(np.random.default_rng(7), n, n_halos=6,
                            noise_frac=0.15)
eps = hacc_benchmark_epsilon(1.0, n)

# Step 1: FOF (minPts=2) to find the halos.
halos = fdbscan(jnp.asarray(pts), eps * 1.5, 2)
labels = np.asarray(halos.labels)
ids, counts = np.unique(labels[labels >= 0], return_counts=True)
biggest = ids[counts.argmax()]
members = pts[labels == biggest]
print(f"largest halo: {len(members)} particles "
      f"(of {n}, {len(ids)} halos found)")

# Step 2: DBSCAN minPts=10 inside the halo = galaxy finding (paper Fig. 2).
gal = fdbscan(jnp.asarray(members), eps, 10)
glabels = np.asarray(gal.labels)
gids = np.unique(glabels[glabels >= 0])
print(f"{len(gids)} galaxies found, {int((glabels < 0).sum())} stellar noise")
for g in gids[:10]:
    m = members[glabels == g]
    center = m.mean(0)
    radius = np.linalg.norm(m - center, axis=1).max()
    print(f"  galaxy {g}: {len(m):5d} stars, center={np.round(center, 3)}, "
          f"radius={radius:.4f}")
assert len(gids) >= 1
