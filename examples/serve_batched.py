"""Serving example: batched prefill + lock-step decode on a smoke model.

  PYTHONPATH=src python examples/serve_batched.py [--arch gemma2-9b]
"""
import argparse
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    args = ap.parse_args()
    sys.exit(serve_main(["--arch", args.arch, "--smoke",
                         "--requests", "4", "--prompt-len", "32",
                         "--gen-tokens", "12"]))
