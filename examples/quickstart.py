"""Quickstart: the paper's contribution in 30 lines.

Cluster a cosmology-style point cloud with FDBSCAN (the ArborX algorithm,
§4.3.3) and with the TPU-native tiled-grid implementation, and check they
agree. Runs on CPU in seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.dbscan import fdbscan
from repro.core.fdbscan_grid import fdbscan_grid, grid_dims_for
from repro.data.pipeline import hacc_benchmark_epsilon, make_clustered_points

# --- the paper's benchmark setup, downscaled -------------------------------
# (CPU demo scale: the paper's ε = b(V/n)^{1/3} at n=37M maps to very fine
# grids; on CPU-interpret we keep the same density REGIME by shrinking n
# and widening ε so the stencil grid stays small.)
n = 512
points = make_clustered_points(np.random.default_rng(0), n)
eps = 4 * hacc_benchmark_epsilon(volume=1.0, n_particles=n)  # b (V/n)^{1/3}
min_pts = 2                                                  # FOF

# --- faithful tier: BVH + stackless traversal + fused union-find -----------
res = fdbscan(jnp.asarray(points), eps, min_pts)
n_noise = int((np.asarray(res.labels) < 0).sum())
print(f"FDBSCAN:  {int((np.asarray(res.labels) >= 0).sum())} clustered, "
      f"{n_noise} noise, union rounds={int(res.num_rounds)}")

# --- TPU-native tier: ε-cell binning + MXU stencil kernels -----------------
dims = grid_dims_for(np.zeros(3), np.ones(3), eps)
res_g, overflowed = fdbscan_grid(
    jnp.asarray(points), eps, min_pts,
    scene_lo=np.zeros(3, np.float32), grid_dims=dims, capacity=256)
assert not bool(overflowed)
print(f"TPU grid: {int((np.asarray(res_g.labels) >= 0).sum())} clustered "
      f"({int(np.prod(dims))} cells x 27-stencil)")

# --- same partitions? -------------------------------------------------------
from repro.core.ref_numpy import labels_equivalent
assert labels_equivalent(np.asarray(res.labels), np.asarray(res_g.labels),
                         np.asarray(res.core_mask))
print("faithful tier and TPU tier agree.")
