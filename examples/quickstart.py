"""Quickstart: the paper's contribution in 30 lines.

Cluster a cosmology-style point cloud with FDBSCAN (the ArborX algorithm,
§4.3.3), tour the unified query API behind it (§4.1), then cross-check
against the TPU-native tiled-grid implementation. The FDBSCAN and
query-API sections run on CPU in seconds; the final grid section runs the
Pallas kernels in interpret mode on CPU and takes several minutes (it is
the fast path on the TPU target).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.dbscan import fdbscan
from repro.core.fdbscan_grid import fdbscan_grid, grid_dims_for
from repro.data.pipeline import hacc_benchmark_epsilon, make_clustered_points

# --- the paper's benchmark setup, downscaled -------------------------------
# (CPU demo scale: the paper's ε = b(V/n)^{1/3} at n=37M maps to very fine
# grids; on CPU-interpret we keep the same density REGIME by shrinking n
# and widening ε so the stencil grid stays small.)
n = 512
points = make_clustered_points(np.random.default_rng(0), n)
eps = 4 * hacc_benchmark_epsilon(volume=1.0, n_particles=n)  # b (V/n)^{1/3}
min_pts = 2                                                  # FOF

# --- faithful tier: BVH + stackless traversal + fused union-find -----------
res = fdbscan(jnp.asarray(points), eps, min_pts)
n_noise = int((np.asarray(res.labels) < 0).sum())
print(f"FDBSCAN:  {int((np.asarray(res.labels) >= 0).sum())} clustered, "
      f"{n_noise} noise, union rounds={int(res.num_rounds)}")

# --- the query API ----------------------------------------------------------
# FDBSCAN above is a thin client of ONE engine (the paper's §4.1 story):
# query(index, predicates, callback). Build the tree once, then dispatch any
# predicate against it — fused callbacks, CSR outputs, kNN — all through the
# same entry point (with Morton query sorting a flip of a switch).
from repro.core.bvh import build_bvh
from repro.core.geometry import scene_bounds
from repro.core.query import (nearest, query, query_count, query_csr,
                              query_csr_device, within)

jp = jnp.asarray(points)
lo, hi = scene_bounds(jp)
bvh = build_bvh(jp, lo, hi)

# 1. range counts with early exit (DBSCAN's core test IS this call;
#    counts saturate at stop_at — only the >= min_pts verdict matters):
counts = query_count(bvh, within(jp, eps), stop_at=min_pts)

# 2. full neighbor lists as count-then-fill CSR. With no capacity, one host
#    sync sizes the output exactly:
csr = query_csr(bvh, within(jp, eps))
offsets, indices = csr.offsets, csr.indices

# 2b. the DEVICE-RESIDENT variant (the ArborX 2.0 contract): pass a capacity
#     bound and the count → exclusive scan → scatter-fill pipeline stays on
#     device end to end — jit-traceable, no sync, overflow reported as a
#     flag. This is the protocol the sharded pipeline builds on (see
#     examples/distributed_halo_finding.py: the whole build → ghost exchange
#     → query → DBSCAN → catalog merge chain runs inside ONE shard_map
#     region with zero host round-trips).
dev = query_csr_device(bvh, within(jp, eps), capacity=64 * n)
assert not bool(dev.overflowed)
assert int(dev.total) == int(csr.offsets[-1])

# 3. a fused callback: sum of neighbor indices, no storage at all —
#    must agree with the CSR materialization of the same predicate:
def cb(acc, q_idx, obj_idx, d2):   # invoked per ε-pair, d2 = squared dist
    return acc + obj_idx, jnp.bool_(False)
sums = query(bvh, within(jp, eps), cb, jnp.int32(0), sort_queries=True)
assert int(sums.sum()) == int(indices.sum())

# 4. k nearest neighbors through the same dispatcher:
nn = query(bvh, nearest(jp[:8], k=4))

print(f"query API: {int((counts >= min_pts).sum())} core points, "
      f"CSR nnz={int(offsets[-1])}, knn[0]={np.asarray(nn.indices[0])}")

# 5. picking a backend. Every spatial call above takes `backend=`:
#
#      backend="stackless"  (default) vmapped rope traversal — one scalar
#                           while-loop per query, XLA schedules the batch.
#      backend="stack"      explicit-stack twin, mainly a correctness oracle.
#      backend="pallas"     ONE batched Pallas wavefront kernel: a block of
#                           Morton-sorted queries advances through the tree
#                           in lockstep, rope hops + fused callback inside a
#                           single while-loop — the GPU-style traversal the
#                           paper credits for its largest wins (§4). Pick it
#                           on TPU targets; on CPU it runs in interpret mode
#                           (correct but slow — CI exercises it that way).
#
#    All three return identical results for query / query_count / query_csr
#    / query_csr_device / query_csr_buffered, including `with_stats=` and
#    `start_nodes=` (cell-grid pruned starts). `nearest()` is the exception:
#    its priority-queue carry is stackless/stack only for now.
counts_p = query_count(bvh, within(jp, eps), backend="pallas",
                       sort_queries=True)
assert bool(jnp.array_equal(counts_p, query_count(bvh, within(jp, eps),
                                                  sort_queries=True)))

# --- observability -----------------------------------------------------------
# Every §4 win in the paper (early termination, stackless ropes, pair
# traversal) came from MEASURING traversal behaviour. `with_stats=True` on
# any spatial query returns a device-resident TraversalStats alongside the
# result — per-query nodes visited, AABB/leaf tests, callback hits, early
# exits and depth high-water mark — with ZERO cost when off (the stats-off
# jaxpr is machine-checked identical to the uninstrumented engine):
from repro.obs import MetricsRegistry, SpanTracer

counts_s, stats = query_count(bvh, within(jp, eps), stop_at=min_pts,
                              with_stats=True)
tot = stats.totals()   # still on device; sums/maxes of the per-query columns
print(f"traversal: {int(tot['nodes_visited'])} nodes, "
      f"{int(tot['callback_hits'])} hits, "
      f"{int(tot['early_exits'])} early exits, depth {int(tot['max_depth'])}")

# Host-side spans fence async dispatch (block_until_ready) so durations
# cover the device work, and export Chrome-trace JSON for ui.perfetto.dev.
# The sharded pipelines take `tracer=` directly (halo_pipeline_traced,
# dbscan_distributed, InsituAnalyzer); a MetricsRegistry unifies the
# engine's observability crumbs (CSR overflow/attempts, traversal stats):
tracer = SpanTracer()
with tracer.span("quickstart_query", n=n) as sp:
    sp.fence(query_count(bvh, within(jp, eps)))
tracer.export("trace_quickstart.json")      # load in ui.perfetto.dev

reg = MetricsRegistry()
reg.observe("quickstart/csr", dev)          # -> total + overflow series
reg.observe("quickstart/query", stats)      # -> counter totals
print(f"metrics: {sorted(reg.summary())}")

# --- static checks ----------------------------------------------------------
# The device-discipline rules this file leans on (no dense staging, no host
# syncs, shard_map jits only via the _maybe_jit gate, consumed overflow
# flags, guarded min-image folds) are machine-checked by `repro.staticcheck`:
#
#   PYTHONPATH=src python -m repro.staticcheck                 # AST lint R1-R4
#   PYTHONPATH=src python -m repro.staticcheck --jaxpr --fast  # + jaxpr audits
#   PYTHONPATH=src python -m repro.staticcheck --json report.json
#
# Exit status is nonzero iff any finding fired; findings carry file:line
# anchors, and a `# staticcheck: <token>` pragma (overflow-ok, minimage-ok,
# bvh-loop-ok, shard-jit-ok, ignore) opts out a deliberate exception. The
# same rules are importable — prove the device CSR call above never stages
# the dense (q × max_count) buffer, then watch the lint catch the ROADMAP
# item 3 f32 trap in a snippet:
from repro.staticcheck import audit_jaxpr, lint_source, no_dense_intermediate

assert audit_jaxpr(
    lambda b: query_csr_device(b, within(jp, eps), capacity=64 * n),
    (bvh,), [no_dense_intermediate(n * n)]) == []

bad = ("import jax.numpy as jnp\n"
       "def fold(d, L):\n"
       "    return d - jnp.round(d / L) * L\n")
print("staticcheck demo:", lint_source(bad, "snippet.py")[0])

# --- scale-safety checks ----------------------------------------------------
# Everything above ran at n=512, but the paper's target is N=1e9 points on
# 64 shards. The third staticcheck layer — an abstract interpreter over the
# traced jaxpr — re-reads the staged toy sizes as SYMBOLIC exascale sizes
# and propagates a value interval per array, proving the W rules without
# materializing anything: W1 index-width (a signed int escapes its dtype),
# W2 precision (float quantization past 2^mantissa — the min-image trap of
# ROADMAP item 3), W3 bounds & routes (unprovable gather indices, broken
# ppermute tables). Here it derives that the int32 CSR offsets of the very
# call audited above overflow at 64e9 total hits:
from repro.staticcheck import SymbolicScale, analyze, scale_for
from repro.staticcheck.lattice import Ival

scale = SymbolicScale(dims=scale_for(n, 10**9, {64 * n: 64 * 10**9}))
rep = analyze(
    lambda b, c: query_csr_device(b, within(jp, eps), capacity=64 * n,
                                  counts=c),
    (bvh, counts), name="quickstart_csr_int32", scale=scale,
    input_ivals=[None, Ival(0, 2048)])
print("scale-safety demo:", rep.findings[0].message)
# The fix is the satellite API: query_csr_device(..., index_dtype=jnp.int64)
# under x64 analyzes clean — CI pins the widened production configs (and
# the seeded broken twins) via `python -m repro.staticcheck --absint`.

# --- TPU-native tier: ε-cell binning + MXU stencil kernels -----------------
# (interpret-mode on CPU: this section takes several minutes here.)
dims = grid_dims_for(np.zeros(3), np.ones(3), eps)
res_g, overflowed = fdbscan_grid(
    jnp.asarray(points), eps, min_pts,
    scene_lo=np.zeros(3, np.float32), grid_dims=dims, capacity=256)
assert not bool(overflowed)
print(f"TPU grid: {int((np.asarray(res_g.labels) >= 0).sum())} clustered "
      f"({int(np.prod(dims))} cells x 27-stencil)")

# --- same partitions? -------------------------------------------------------
from repro.core.ref_numpy import labels_equivalent
assert labels_equivalent(np.asarray(res.labels), np.asarray(res_g.labels),
                         np.asarray(res.core_mask))
print("faithful tier and TPU tier agree.")
