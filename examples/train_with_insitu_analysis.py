"""End-to-end driver (deliverable (b)): train a ~100M-param model for a few
hundred steps with the paper's technique running in-situ — exactly the HACC
pattern (solver steps + in-situ DBSCAN analysis at a cadence), plus async
checkpointing and the straggler watchdog.

  PYTHONPATH=src python examples/train_with_insitu_analysis.py \
      [--steps 300] [--full-100m]

--full-100m trains the real xlstm-350m config minus depth (~100M params);
the default is the smoke config so CI finishes in ~2 minutes.
"""
import argparse
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()

    argv = ["--arch", "xlstm-350m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128",
            "--ckpt-dir", "/tmp/repro_e2e_ckpt",
            "--insitu-every", "25", "--ckpt-every", "100"]
    if not args.full_100m:
        argv.append("--smoke")
    sys.exit(train_main(argv))
