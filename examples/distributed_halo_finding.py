"""Distributed halo finding example: HACC's MPI domain decomposition as
shard_map + collectives, on 8 simulated devices — first stage by stage
(DBSCAN, then catalog), then the whole thing again through
``halo_pipeline_sharded``: build → ghost exchange → query → DBSCAN →
catalog merge → SO masses fused into ONE shard_map region with zero host
round-trips between stages.

NOTE: sets XLA_FLAGS before importing jax — run as a script, not import.

  PYTHONPATH=src python examples/distributed_halo_finding.py
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distributed import dbscan_distributed, slab_partition
from repro.core.ref_numpy import core_mask_ref, dbscan_ref, labels_equivalent
from repro.data.pipeline import hacc_benchmark_epsilon, make_clustered_points

try:  # axis_types only exists on newer JAX
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
except (AttributeError, TypeError):
    mesh = jax.make_mesh((8,), ("data",))
n = 1024
pts = make_clustered_points(np.random.default_rng(1), n)
eps = hacc_benchmark_epsilon(1.0, n)

# Domain decomposition: each "rank" owns a contiguous slab along x.
pts_sorted, _ = slab_partition(pts, 8)
res = dbscan_distributed(jnp.asarray(pts_sorted), eps, 2, mesh=mesh,
                         halo_cap=1024)
print(f"distributed FOF over 8 shards: rounds={int(res.rounds)} "
      f"halo_overflow={bool(res.halo_overflow)}")
labels = np.asarray(res.labels)
print(f"{int((labels >= 0).sum())} clustered / {n}, "
      f"{len(np.unique(labels[labels >= 0]))} clusters")

# cross-check against the single-node oracle
ref = dbscan_ref(pts_sorted, eps, 2)
core = core_mask_ref(pts_sorted, eps, 2)
assert labels_equivalent(labels, ref, core)
print("matches the single-node oracle.")

# --- the production step: sharded labels -> merged halo catalog -------------
from repro.halos import halo_catalog, halo_catalog_sharded

vel = np.random.default_rng(2).standard_normal((n, 3)).astype(np.float32)
cat = halo_catalog_sharded(jnp.asarray(pts_sorted), jnp.asarray(vel),
                           res.labels, mesh=mesh, capacity=128, min_count=10)
single = halo_catalog(jnp.asarray(pts_sorted), jnp.asarray(vel), res.labels,
                      capacity=128, min_count=10)
assert int(cat.num_halos) == int(single.num_halos)
np.testing.assert_allclose(np.asarray(cat.center), np.asarray(single.center),
                           atol=1e-5)
nh = int(cat.num_halos)
top = np.argsort(-np.asarray(cat.count[:nh]))[:5]
print(f"merged catalog across 8 shards: {nh} halos (>=10 particles); top 5:")
for h in top:
    print(f"  root={int(cat.root[h]):4d} count={int(cat.count[h]):4d} "
          f"center={np.round(np.asarray(cat.center[h]), 3)} "
          f"vdisp={float(cat.vdisp[h]):.3f} rmax={float(cat.rmax[h]):.4f}")
print("sharded catalog == single-device catalog.")

# --- the fused pipeline: everything above in ONE shard_map region -----------
# (per-shard BVH build, ε-ghost exchange, engine-traversal DBSCAN, catalog
# merge, max-radius pass, SO masses — one device launch, no host syncs.)
from repro.halos import halo_pipeline_sharded

pipe = halo_pipeline_sharded(
    jnp.asarray(pts_sorted), jnp.asarray(vel), eps, 2, mesh=mesh,
    capacity=128, halo_cap=1024, min_count=10, so_delta=200.0)
assert labels_equivalent(np.asarray(pipe.labels), ref, core)
assert int(pipe.catalog.num_halos) == nh
np.testing.assert_allclose(np.asarray(pipe.catalog.center),
                           np.asarray(cat.center), atol=1e-5)
nb = int(np.asarray(pipe.so.bracketed).sum())
print(f"fused pipeline: rounds={int(pipe.rounds)}, {nh} halos, "
      f"SO masses bracketed for {nb}; one shard_map region end to end.")
