"""The full ArborX analysis surface on one dataset (paper §3.2): kNN,
Euclidean MST, 2-point correlation, MLS interpolation, ray casting.

  PYTHONPATH=src python examples/analysis_suite.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (build_bvh, build_bvh_objects, emst, knn,
                        mls_interpolate, raycast, two_point_correlation)
from repro.data.pipeline import make_clustered_points

n = 512
pts = make_clustered_points(np.random.default_rng(2), n)
jp = jnp.asarray(pts)
lo, hi = pts.min(0) - 1e-4, pts.max(0) + 1e-4
bvh = build_bvh(jp, jnp.asarray(lo), jnp.asarray(hi))

# --- nearest search (§3.2 "range and nearest") ------------------------------
nn = knn(bvh, jp, jp[:8], k=4)
print("kNN: first point's 4 nearest:", np.asarray(nn.indices[0]),
      "dists", np.round(np.asarray(nn.distances[0]), 4))

# --- Euclidean MST (ArborX clustering functionality) ------------------------
tree = emst(jp)
print(f"EMST: {int((np.asarray(tree.edges) >= 0).all(1).sum())} edges, "
      f"total weight {float(tree.total_weight):.3f}, "
      f"Boruvka rounds {int(tree.rounds)}")

# --- 2-point correlation (§4.2.3's pair-operation example) ------------------
xi, dd, edges = two_point_correlation(jp, r_max=0.25, n_bins=8)
print("xi(r) per bin:", np.round(xi, 2), "(clustered => xi >> 0 at small r)")

# --- MLS interpolation (§3.2 interpolation functionality) -------------------
values = jnp.asarray(np.sin(4 * pts[:, 0]) + pts[:, 1] ** 2, jnp.float32)
targets = jnp.asarray(np.random.default_rng(3).uniform(0.2, 0.8, (5, 3)),
                      jnp.float32)
interp = mls_interpolate(jp, values, targets, k=10)
truth = np.sin(4 * np.asarray(targets)[:, 0]) + np.asarray(targets)[:, 1] ** 2
print("MLS interp err:", np.round(np.abs(np.asarray(interp) - truth), 4))

# --- ray casting (§3.2 ray tracing functionality) ---------------------------
box_lo = jnp.asarray(pts[:64] - 0.01)
box_hi = jnp.asarray(pts[:64] + 0.01)
rbvh = build_bvh_objects(box_lo, box_hi, jnp.asarray(lo), jnp.asarray(hi))
origins = jnp.zeros((4, 3), jnp.float32)
dirs = jnp.asarray(pts[:4] / np.linalg.norm(pts[:4], axis=1, keepdims=True),
                   jnp.float32)
hits = raycast(rbvh, origins, dirs)
print("raycast hits:", np.asarray(hits.index), "t:", np.round(np.asarray(hits.t), 3))
