"""End-to-end halo-catalog pipeline: the paper's production deliverable.

Synthetic Plummer-sphere "halos" with self-consistent velocity dispersions
+ uniform background noise -> FDBSCAN labels -> fixed-capacity halo catalog
(counts, centers of mass, mean velocities, velocity dispersions, max radii)
-> most-bound proxy centers -> spherical-overdensity masses. Every stage is
validated in-line:

* catalog (pure-JAX path) vs the numpy oracle ``halo_catalog_ref`` (1e-5);
* Pallas segmented-reduction path vs pure-JAX path (1e-5);
* recovered velocity dispersions vs each sphere's input dispersion.

  PYTHONPATH=src python examples/halo_catalog.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.dbscan import fdbscan
from repro.core.ref_numpy import halo_catalog_ref
from repro.halos import halo_catalog, most_bound_centers, so_masses

N_SPHERES = 5
N_PER = 350
N_NOISE = 250
CAPACITY = 64
MIN_PTS = 8


def plummer_sphere(rng, n, center, a=0.01, mtot=1.0):
    """Plummer (1911) profile: r from the inverse CDF, isotropic positions,
    Maxwellian velocities at the local dispersion σ²(r) ∝ (r² + a²)^(-1/2)."""
    u = rng.uniform(0.02, 0.98, n)
    r = a / np.sqrt(u ** (-2.0 / 3.0) - 1.0)
    direction = rng.standard_normal((n, 3))
    direction /= np.linalg.norm(direction, axis=1, keepdims=True)
    pos = center + r[:, None] * direction
    sigma2 = mtot / (6.0 * np.sqrt(r ** 2 + a ** 2))  # G = 1
    vel = rng.standard_normal((n, 3)) * np.sqrt(sigma2)[:, None]
    return pos.astype(np.float32), vel.astype(np.float32)


def main():
    rng = np.random.default_rng(42)
    centers = rng.uniform(0.2, 0.8, (N_SPHERES, 3))
    parts_p, parts_v, truth_sigma = [], [], []
    for c in centers:
        p, v = plummer_sphere(rng, N_PER, c)
        parts_p.append(p)
        parts_v.append(v)
        truth_sigma.append(np.sqrt((v ** 2).sum(1).mean()
                                   - (v.mean(0) ** 2).sum()))
    parts_p.append(rng.uniform(0, 1, (N_NOISE, 3)).astype(np.float32))
    parts_v.append(np.zeros((N_NOISE, 3), np.float32))
    pts = np.clip(np.concatenate(parts_p), 0.0, 1.0 - 1e-6)
    vel = np.concatenate(parts_v)
    n = len(pts)

    eps = 0.008
    res = fdbscan(jnp.asarray(pts), eps, MIN_PTS)
    labels = np.asarray(res.labels)
    print(f"{n} particles -> {len(np.unique(labels[labels >= 0]))} clusters, "
          f"{int((labels < 0).sum())} noise")

    cat = halo_catalog(jnp.asarray(pts), jnp.asarray(vel), res.labels,
                       capacity=CAPACITY, min_count=MIN_PTS, backend="jax")
    cat_pl = halo_catalog(jnp.asarray(pts), jnp.asarray(vel), res.labels,
                          capacity=CAPACITY, min_count=MIN_PTS,
                          backend="pallas")
    ref = halo_catalog_ref(pts, vel, labels, CAPACITY, MIN_PTS)

    # --- validation: JAX path vs numpy oracle, Pallas path vs JAX path ----
    assert int(cat.num_halos) == ref["num_halos"]
    np.testing.assert_array_equal(np.asarray(cat.count), ref["count"])
    np.testing.assert_allclose(np.asarray(cat.center), ref["center"], atol=1e-5)
    np.testing.assert_allclose(np.asarray(cat.vmean), ref["vmean"], atol=1e-5)
    np.testing.assert_allclose(np.asarray(cat.vdisp), ref["vdisp"], atol=1e-5)
    np.testing.assert_allclose(np.asarray(cat.rmax), ref["rmax"], atol=1e-5)
    for a, b in zip(cat_pl, cat):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    print("catalog == numpy oracle (1e-5); Pallas path == JAX path (1e-5)")

    # One BVH serves both downstream stages (no per-stage rebuild).
    from repro.core.bvh import build_bvh
    from repro.core.geometry import scene_bounds
    lo, hi = scene_bounds(jnp.asarray(pts))
    bvh = build_bvh(jnp.asarray(pts), lo, hi)
    mb = most_bound_centers(jnp.asarray(pts), cat.particle_halo, eps * 2,
                            capacity=CAPACITY, bvh=bvh)
    so = so_masses(jnp.asarray(pts), mb.center, cat.count > 0,
                   delta=200.0, r_max=0.1, bvh=bvh)

    nh = int(cat.num_halos)
    print(f"\n{'halo':>4} {'count':>6} {'sigma_v':>8} {'sigma_in':>8} "
          f"{'rmax':>7} {'M200':>7} {'R200':>7}")
    order = np.argsort(-np.asarray(cat.count[:nh]))
    for h in order:
        # match recovered halo to the nearest input sphere
        k = int(np.argmin(((centers - np.asarray(cat.center[h])) ** 2).sum(1)))
        print(f"{h:>4} {int(cat.count[h]):>6} {float(cat.vdisp[h]):>8.4f} "
              f"{truth_sigma[k]:>8.4f} {float(cat.rmax[h]):>7.4f} "
              f"{float(so.m_delta[h]):>7.1f} {float(so.r_delta[h]):>7.4f}")

    # dispersion recovery: every big halo within 25% of its sphere's truth
    for h in order:
        if int(cat.count[h]) < 0.5 * N_PER:
            continue
        k = int(np.argmin(((centers - np.asarray(cat.center[h])) ** 2).sum(1)))
        rel = abs(float(cat.vdisp[h]) - truth_sigma[k]) / truth_sigma[k]
        assert rel < 0.25, (h, rel)
    assert nh >= 1
    print("\nOK: dispersions recovered, SO masses computed")


if __name__ == "__main__":
    main()
