"""Sharded-pipeline benchmark: the end-to-end on-device query path.

Times the three layers of the device-resident multi-shard stack on simulated
host devices (run in a SUBPROCESS so ``--xla_force_host_platform_device_count``
is set before jax initializes):

  * ``sharded_neighbor_csr`` — build → ghost exchange → device CSR,
  * ``dbscan_distributed``   — + engine-traversal DBSCAN fixpoint,
  * ``halo_pipeline_sharded`` — + catalog merge (the full fused region).

Alongside wall times it records what the device-resident protocol buys:

  * host syncs per CSR query: two-pass = 1 (the sizing ``int()``), buffered =
    measured retry attempts, device-resident = 0;
  * CSR staging memory on a SKEWED neighborhood distribution (one query
    matching everything): the dense staging a (q × max_count) gather would
    need vs. the device protocol's ``capacity + (q+1) + q·chunk`` words.

Emits CSV lines plus a ``BENCH_distributed.json`` artifact.

  PYTHONPATH=src python -m benchmarks.distributed_pipeline [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import textwrap

_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
    import json, time
    import numpy as np, jax, jax.numpy as jnp
    try:  # axis_types only exists on newer JAX
        mesh = jax.make_mesh(({ndev},), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except (AttributeError, TypeError):
        mesh = jax.make_mesh(({ndev},), ("data",))

    from benchmarks.common import benchmark_points, timeit
    from repro.core.distributed import (dbscan_distributed, slab_partition,
                                        sharded_neighbor_csr)
    from repro.halos import halo_pipeline_sharded

    n = {n}
    pts, eps = benchmark_points(n)
    pts, _ = slab_partition(pts, {ndev})
    jp = jnp.asarray(pts)
    vel = jnp.asarray(np.random.default_rng(1)
                      .standard_normal((n, 3)).astype(np.float32))

    out = {{}}
    t = timeit(lambda: sharded_neighbor_csr(
        jp, eps, capacity=32 * n, mesh=mesh, halo_cap=n).indices, iters=2)
    out["neighbor_csr"] = t
    t = timeit(lambda: dbscan_distributed(
        jp, eps, 2, mesh=mesh, halo_cap=n).labels, iters=2)
    out["dbscan"] = t
    t = timeit(lambda: halo_pipeline_sharded(
        jp, vel, eps, 2, mesh=mesh, capacity=n, halo_cap=n,
        min_count=2).labels, iters=2)
    out["pipeline"] = t

    # buffered-protocol retry count on the same local problem (the only
    # protocol whose host-sync count is data-dependent).
    from repro.core.bvh import build_bvh
    from repro.core.geometry import scene_bounds
    from repro.core.query import query_csr_buffered, within
    lo, hi = scene_bounds(jp)
    bvh = build_bvh(jp, lo, hi)
    buf = query_csr_buffered(bvh, within(jp, eps), capacity=8)
    out["buffered_attempts"] = int(buf.attempts)

    # One traced pass through both entry points: fenced spans around the
    # fused launch + per-stage spans from the staged pipeline, exported as
    # Chrome-trace JSON (load in ui.perfetto.dev).
    from repro.obs import SpanTracer
    from repro.halos.merge import halo_pipeline_traced
    tracer = SpanTracer(process_name="distributed_pipeline")
    sharded_neighbor_csr(jp, eps, capacity=32 * n, mesh=mesh, halo_cap=n,
                         tracer=tracer)
    halo_pipeline_traced(jp, vel, eps, 2, mesh=mesh, capacity=n,
                         halo_cap=n, min_count=2, tracer=tracer)
    tracer.export({trace_path!r})
    out["trace_spans"] = sum(1 for e in tracer.events if e["ph"] == "X")
    print("JSON:" + json.dumps(out))
""")


def _staging_words(q: int, max_count: int, capacity: int, chunk: int) -> dict:
    """Analytic CSR staging footprint (int32 words) for a q-query batch."""
    return {
        "dense_gather": q * max_count,
        "device_csr": capacity + (q + 1) + q * chunk,
    }


def main(fast: bool = False, out_path: str = "BENCH_distributed.json",
         trace_path: str = "trace_distributed.json") -> None:
    from benchmarks.common import emit

    ndev = 2 if fast else 4
    n = 256 if fast else 1024
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(pathlib.Path(__file__).resolve().parent.parent / "src"),
         str(pathlib.Path(__file__).resolve().parent.parent),
         env.get("PYTHONPATH", "")])
    env.pop("XLA_FLAGS", None)
    code = _CHILD.format(ndev=ndev, n=n, trace_path=trace_path)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    child = json.loads(proc.stdout.strip().rsplit("JSON:", 1)[1])

    results: dict = {}
    for stage in ("neighbor_csr", "dbscan", "pipeline"):
        t = child[stage]
        name = f"distributed/{stage}_n{n}_s{ndev}"
        emit(name, t, derived=f"shards={ndev};points_per_s={n / max(t, 1e-12):.0f}")
        results[name] = {"seconds": t, "n": n, "shards": ndev, "stage": stage}

    # host syncs per CSR query, by output protocol
    syncs = {"two_pass": 1, "buffered": child["buffered_attempts"], "device": 0}
    for proto, k in syncs.items():
        emit(f"distributed/host_syncs_{proto}", 0.0, derived=f"syncs={k}")
    results["distributed/host_syncs"] = syncs

    # skewed vs uniform staging memory (words), q = n queries
    cap, chunk = 32 * n, 32
    skew = _staging_words(q=n, max_count=n, capacity=cap, chunk=chunk)
    unif = _staging_words(q=n, max_count=64, capacity=cap, chunk=chunk)
    for label, w in (("skewed", skew), ("uniform", unif)):
        emit(f"distributed/staging_{label}", 0.0,
             derived=f"dense_words={w['dense_gather']};"
                     f"device_words={w['device_csr']}")
    results["distributed/staging_words"] = {"skewed": skew, "uniform": unif}

    emit("distributed/trace_spans", 0.0,
         derived=f"spans={child['trace_spans']};file={trace_path}")

    pathlib.Path(out_path).write_text(json.dumps(results, indent=2))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.fast)
