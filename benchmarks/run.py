"""Benchmark aggregator: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller problem sizes")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (distributed_pipeline, fig1_insitu, fig4_timeline,
                            halo_pipeline, kernels_micro, query_micro,
                            roofline_report, table1_morton)

    suites = {
        "table1": lambda: table1_morton.main(n=(1 << 15) if args.fast else (1 << 18)),
        "fig4": lambda: fig4_timeline.ladder(n=512 if args.fast else 2048),
        "fig1": lambda: fig1_insitu.main(fast=args.fast),
        "roofline": lambda: roofline_report.main(fast=args.fast),
        "kernels": kernels_micro.main,
        "halos": lambda: halo_pipeline.main(fast=args.fast),
        "query": lambda: query_micro.main(fast=args.fast),
        "distributed": lambda: distributed_pipeline.main(fast=args.fast),
    }
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
