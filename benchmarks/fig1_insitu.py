"""Figure 1 reproduction (transplanted): in-situ analysis cost inside the
training loop, fast (FDBSCAN) vs slow (adjacency-graph baseline) clustering.

HACC's claim: ArborX made FOF ~10-12x faster than the tuned CPU baseline;
at ~100 analysis steps per 625 solver steps, the full time-stepper sped up
~2x, and analysis could move to EVERY step. Here: one smoke-model training
step is the 'solver step'; the analysis step clusters sampled embeddings.
We report the analysis:solver ratio under both clustering backends and the
implied full-loop speedup at the paper's cadence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.insitu import InsituConfig, embedding_cluster_stats
from repro.configs import get_config
from repro.core.dbscan import dbscan_graph_cc, fdbscan
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch import steps
from repro.models import lm
from repro.models.spec import init_params
from repro.optim import adamw
from benchmarks.common import emit, timeit, write_artifact


def main(fast: bool = False, out_path: str = "BENCH_fig1.json") -> None:
    cfg = get_config("xlstm-350m").smoke()
    params = init_params(lm.model_spec(cfg), jax.random.PRNGKey(0), jnp.float32)
    opt_cfg = adamw.OptConfig(moment_dtype="float32")
    state = steps.TrainState(params, adamw.init_opt_state(opt_cfg, params))
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=8))
    batch = data.batch_at(0)
    jit_step = jax.jit(functools.partial(steps.train_step, cfg=cfg,
                                         opt_cfg=opt_cfg))
    t_solver = timeit(lambda: jit_step(state, batch), iters=3)
    emit("fig1_solver_step", t_solver, "smoke train step")

    icfg = InsituConfig(sample_rows=min(192 if fast else 384, cfg.vocab))
    key = jax.random.PRNGKey(1)
    rows = params["embed"][jax.random.choice(key, cfg.vocab, (icfg.sample_rows,),
                                             replace=False)]
    from repro.analysis.insitu import _eps_from_quantile, _project
    pts = _project(key, rows, 3)
    eps = float(_eps_from_quantile(pts, 0.02))

    cap = icfg.sample_rows
    t_fast = timeit(lambda: fdbscan(pts, eps, 2))
    t_slow = timeit(lambda: dbscan_graph_cc(pts, eps, 2, neighbor_capacity=cap))
    emit("fig1_analysis_fdbscan", t_fast, f"eps={eps:.4f}")
    emit("fig1_analysis_graph_cc", t_slow, f"slowdown={t_slow / t_fast:.2f}x")

    # Paper cadence: 100 analysis steps per 625 solver steps.
    loop_fast = 625 * t_solver + 100 * t_fast
    loop_slow = 625 * t_solver + 100 * t_slow
    emit("fig1_full_loop_speedup", loop_slow - loop_fast,
         f"timestepper_speedup={loop_slow / loop_fast:.2f}x;paper~2x")
    # every-step analysis budget (the paper's new capability)
    every = t_fast / t_solver
    emit("fig1_everystep_overhead", t_fast,
         f"analysis/solver={every:.2%} per-step at cadence 1")

    rows = icfg.sample_rows
    write_artifact(out_path, {
        f"fig1/solver_step_r{rows}": {"seconds": t_solver, "rows": rows},
        f"fig1/analysis_fdbscan_r{rows}": {"seconds": t_fast, "rows": rows},
        f"fig1/analysis_graph_cc_r{rows}": {
            "seconds": t_slow, "rows": rows,
            "slowdown_vs_fdbscan": round(t_slow / t_fast, 2)},
        # "seconds": 0.0 -> compare.py treats these as timing records but
        # skips the tolerance band (derived ratios, not wall-clock).
        f"fig1/full_loop_speedup_r{rows}": {
            "seconds": 0.0, "rows": rows,
            "timestepper_speedup": round(loop_slow / loop_fast, 2),
            "paper_speedup": 2.0},
        f"fig1/everystep_overhead_r{rows}": {
            "seconds": 0.0, "rows": rows,
            "analysis_over_solver": round(every, 4)},
    })


if __name__ == "__main__":
    main()
