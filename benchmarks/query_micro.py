"""Query-engine micro-benchmark: backend × output-protocol grid.

Times the unified engine (core/query.py) on the paper's benchmark problem
so the cost of each output protocol is tracked per backend:

  protocols: fused-callback count (the §4.1.1 baseline: no storage),
             two-pass count-then-fill CSR (§4.1; one sizing host sync),
             device-resident scan-then-scatter CSR (fixed capacity,
             zero host syncs — the ArborX 2.0 contract),
             single-pass buffered CSR (the §4.1 buffer optimization —
             timed with a capacity that holds, i.e. the zero-retry
             common case),
  backends:  stackless (rope), stack, and the Pallas wavefront kernel
             (interpret mode on CPU — the column tracks dispatch/padding
             overhead there; native timings need a TPU, see
             benchmarks/kernels_micro.py and REPRO_TPU=1), plus the pair
             backend's fused count for the self-join workloads.

Emits the usual CSV lines plus a ``BENCH_query.json`` artifact so CSR
two-pass vs. fused-callback cost rides along the existing benches.

  PYTHONPATH=src python -m benchmarks.query_micro [--fast]
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import benchmark_points, emit, timeit, write_artifact
from repro.core.bvh import build_bvh
from repro.core.geometry import scene_bounds
from repro.core.query import (query, query_count, query_csr,
                              query_csr_buffered, query_csr_device, within)


def _grid(n: int, results: dict) -> None:
    pts, eps = benchmark_points(n)
    jp = jnp.asarray(pts)
    lo, hi = scene_bounds(jp)
    bvh = build_bvh(jp, lo, hi)
    pred = within(jp, eps)
    max_count = int(jnp.max(query_count(bvh, pred)))
    # a capacity the buffered pass never overflows at: the zero-retry case
    cap0 = 1 << max(1, int(np.ceil(np.log2(max_count))))

    def pair_count():
        def cb(c, i, j, d2):
            return c + 1, jnp.bool_(False)
        return query(bvh, pred, cb, jnp.int32(0), backend="pair")

    backends = ("stackless", "stack", "pallas")
    runs = [("count", b, lambda b=b: query_count(bvh, pred, backend=b))
            for b in backends]
    runs += [("csr_two_pass", b,
              lambda b=b: query_csr(bvh, pred, backend=b).indices)
             for b in backends]
    # device-resident CSR: fixed capacity, no host sync anywhere
    cap_dev = n * cap0
    runs += [("csr_device", b,
              lambda b=b: query_csr_device(bvh, pred, cap_dev,
                                           backend=b).indices)
             for b in backends]
    runs += [("csr_buffered", b,
              lambda b=b: query_csr_buffered(bvh, pred, capacity=cap0,
                                             backend=b).indices)
             for b in backends]
    runs.append(("count", "pair", pair_count))

    for protocol, backend, fn in runs:
        t = timeit(fn, iters=2)
        name = f"query/{protocol}_{backend}_n{n}"
        emit(name, t, derived=f"max_count={max_count};"
                              f"queries_per_s={n / max(t, 1e-12):.0f}")
        results[name] = {"seconds": t, "n": n, "protocol": protocol,
                         "backend": backend, "max_count": max_count}


def main(fast: bool = False, out_path: str = "BENCH_query.json") -> None:
    results: dict = {}
    for n in ([512] if fast else [2048, 8192]):
        _grid(n, results)
    write_artifact(out_path, results)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.fast)
