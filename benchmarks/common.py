"""Shared benchmark utilities: timing, the benchmark dataset (paper §4),
and the ``BENCH_*.json`` artifact writer for the regression gate."""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax

from repro.data.pipeline import hacc_benchmark_epsilon, make_clustered_points


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over iters (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def benchmark_points(n: int, seed: int = 0) -> tuple[np.ndarray, float]:
    """The paper's benchmark problem, downscaled: clustered NFW-like points
    in the unit box with ε = b (V/n)^{1/3}, b = 0.168 (paper footnote 1).
    The paper's snapshot is 37M points on an A100; CPU benches use n ≤ ~10^5
    with the SAME ε convention so the density regime matches."""
    pts = make_clustered_points(np.random.default_rng(seed), n)
    eps = hacc_benchmark_epsilon(1.0, n)
    return pts, eps


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def write_artifact(out_path: str, results: dict) -> None:
    """Write a ``BENCH_*.json`` artifact for ``benchmarks.compare``.

    Keep every field inside a record that carries ``seconds``:
    ``compare`` tolerance-bands the ``seconds`` value and ignores the rest,
    while a record WITHOUT ``seconds`` becomes an exact-match contract —
    too brittle for anything derived from timings or platform specifics.
    """
    pathlib.Path(out_path).write_text(json.dumps(results, indent=2))
