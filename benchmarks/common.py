"""Shared benchmark utilities: timing, the benchmark dataset (paper §4),
and the ``BENCH_*.json`` artifact writer for the regression gate."""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax

from repro.data.pipeline import hacc_benchmark_epsilon, make_clustered_points


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over iters (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def benchmark_points(n: int, seed: int = 0) -> tuple[np.ndarray, float]:
    """The paper's benchmark problem, downscaled: clustered NFW-like points
    in the unit box with ε = b (V/n)^{1/3}, b = 0.168 (paper footnote 1).
    The paper's snapshot is 37M points on an A100; CPU benches use n ≤ ~10^5
    with the SAME ε convention so the density regime matches."""
    pts = make_clustered_points(np.random.default_rng(seed), n)
    eps = hacc_benchmark_epsilon(1.0, n)
    return pts, eps


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def write_artifact(out_path: str, results: dict) -> None:
    """Write a ``BENCH_*.json`` artifact for ``benchmarks.compare``.

    Keep every field inside a record that carries ``seconds``:
    ``compare`` tolerance-bands the ``seconds`` value and ignores the rest,
    while a record WITHOUT ``seconds`` becomes an exact-match contract —
    too brittle for anything derived from timings or platform specifics.

    Every artifact also carries a ``staticcheck_absint`` metadata record:
    the scale-safety coverage summary (rules, entry points, values
    analyzed, findings) for the tree the numbers were measured on, so a
    benchmark result can be traced to a scale-audited build. Its
    ``seconds`` is pinned at 0.0 — records at 0.0 never trip the timing
    gate — and the memoized pass costs ~1s once per process.
    """
    results = dict(results)
    results.setdefault("staticcheck_absint", _absint_block())
    pathlib.Path(out_path).write_text(json.dumps(results, indent=2))


def _absint_block() -> dict:
    try:
        from repro.staticcheck.absint_registry import absint_coverage
        return absint_coverage()
    except Exception as exc:  # never fail a benchmark run over metadata
        return {"seconds": 0.0, "error": f"{type(exc).__name__}: {exc}"}
