"""Table 1 reproduction: 32- vs 64-bit Morton code collision statistics on
the clustered benchmark problem.

Paper (37M points): 23.5M points shared a 32-bit code (max 3,569 per code),
while 64-bit left 528 (max 2). The phenomenon is density-driven, so it
reproduces qualitatively at smaller n with the same ε convention.

Emits the usual CSV lines plus a ``BENCH_table1.json`` artifact (encode and
sort timings, collision stats as metadata) for the ``benchmarks.compare``
regression gate.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import morton
from benchmarks.common import benchmark_points, emit, timeit, write_artifact


def stats(codes: np.ndarray) -> dict:
    _, counts = np.unique(codes, return_counts=True)
    dup = counts[counts > 1]
    return {
        "dup_codes_gt3": int((counts > 3).sum()),
        "points_with_dup": int(dup.sum()),
        "max_same_code": int(counts.max()),
    }


def main(n: int = 1 << 20, out_path: str = "BENCH_table1.json") -> None:
    pts, eps = benchmark_points(n)
    jp = jnp.asarray(pts)
    lo = jp.min(0) - 1e-6
    hi = jp.max(0) + 1e-6
    unit = morton.normalize_points(jp, lo, hi)

    c32 = np.asarray(morton.morton32(unit))
    h, l = morton.morton64(unit)
    c64 = (np.asarray(h).astype(np.uint64) << np.uint64(32)) \
        | np.asarray(l).astype(np.uint64)

    s32, s64 = stats(c32), stats(c64)
    results: dict = {}
    t_enc32 = timeit(lambda: morton.morton32(unit))
    t_enc64 = timeit(lambda: morton.morton64(unit))
    emit("table1_32bit", t_enc32,
         f"n={n};dup_codes_gt3={s32['dup_codes_gt3']};"
         f"points_with_dup={s32['points_with_dup']};max={s32['max_same_code']}")
    emit("table1_64bit", t_enc64,
         f"n={n};dup_codes_gt3={s64['dup_codes_gt3']};"
         f"points_with_dup={s64['points_with_dup']};max={s64['max_same_code']}")
    results[f"table1/encode32_n{n}"] = {"seconds": t_enc32, "n": n, **s32}
    results[f"table1/encode64_n{n}"] = {"seconds": t_enc64, "n": n, **s64}

    # Paper's qualitative claim: 64-bit eliminates nearly all duplicates.
    assert s64["points_with_dup"] <= max(1, s32["points_with_dup"] // 100)

    # sort cost ratio (the documented 64-bit drawback)
    t32 = timeit(lambda: morton.sort_by_morton32(morton.morton32(unit)))
    t64 = timeit(lambda: morton.sort_by_morton64(*morton.morton64(unit)))
    emit("table1_sort_cost", t64, f"sort64_vs_sort32={t64 / t32:.2f}x")
    results[f"table1/sort32_n{n}"] = {"seconds": t32, "n": n}
    results[f"table1/sort64_n{n}"] = {"seconds": t64, "n": n,
                                      "vs_sort32": round(t64 / max(t32, 1e-12), 2)}
    write_artifact(out_path, results)


if __name__ == "__main__":
    main()
