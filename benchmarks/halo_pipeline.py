"""Halo-pipeline benchmark: labels -> catalog throughput (new workload).

Times the catalog stage of the in-situ pipeline — canonicalization +
segmented reductions + mass cut — on synthetic power-law halo populations
(labels generated directly so the timing isolates the NEW subsystem, not
the DBSCAN ladder benchmarked in fig4). Sizes span 1e5–1e7 particles
(``--fast``: 1e4).

Emits the usual CSV lines plus a ``BENCH_halos.json`` artifact so the perf
trajectory of this workload is tracked from the PR that introduced it.

  PYTHONPATH=src python -m benchmarks.halo_pipeline [--fast]
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.halos.catalog import halo_catalog

CAPACITY = 4096


def synthetic_labels(rng: np.random.Generator, n: int,
                     n_halos: int, noise_frac: float = 0.2) -> np.ndarray:
    """Power-law halo mass function: sizes ~ Pareto, labels = root ids
    (min member index per halo, matching the DBSCAN convention)."""
    w = rng.pareto(1.3, n_halos) + 1
    sizes = rng.multinomial(int(n * (1 - noise_frac)), w / w.sum())
    halo_of = np.repeat(np.arange(n_halos), sizes)        # (m,) clustered rows
    positions = rng.permutation(n)[:len(halo_of)]          # original indices
    roots = np.full(n_halos, n, np.int64)
    np.minimum.at(roots, halo_of, positions)               # root = min member
    labels = np.full(n, -1, np.int64)
    labels[positions] = roots[halo_of]
    return labels.astype(np.int32)


def bench_catalog(n: int, results: dict, *, pallas_limit: int) -> None:
    rng = np.random.default_rng(n)
    # keep the population inside CAPACITY so the timed run never truncates
    n_halos = min(max(8, n // 2000), CAPACITY)
    labels = jnp.asarray(synthetic_labels(rng, n, n_halos))
    pts = jnp.asarray(rng.uniform(0, 1, (n, 3)).astype(np.float32))
    vel = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))

    backends = ["jax"]
    # Pallas interpret mode (CPU) is python-speed; only time it natively or
    # at small n so the CSV stays honest about what ran.
    if jax.default_backend() == "tpu" or n <= pallas_limit:
        backends.append("pallas")
    for backend in backends:
        def run():
            return halo_catalog(pts, vel, labels, capacity=CAPACITY,
                                min_count=10, backend=backend)

        # warm (compiles) + capture the overflow flag, then time warmup-free
        overflow = bool(jax.block_until_ready(run()).overflow)
        t = timeit(run, warmup=0)
        name = f"halos/catalog_{backend}_n{n}"
        emit(name, t, derived=f"{n / max(t, 1e-12) / 1e6:.2f}Mp/s")
        results[name] = {"seconds": t, "n": n, "backend": backend,
                         "particles_per_s": n / max(t, 1e-12),
                         "overflow": overflow}


def main(fast: bool = False, out_path: str = "BENCH_halos.json") -> None:
    sizes = [10 ** 4] if fast else [10 ** 5, 10 ** 6, 10 ** 7]
    pallas_limit = 10 ** 4 if fast else 10 ** 5
    results: dict = {}
    for n in sizes:
        bench_catalog(n, results, pallas_limit=pallas_limit)
    pathlib.Path(out_path).write_text(json.dumps(results, indent=2))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.fast)
