"""Microbenchmarks for the Pallas kernels and their jnp references.

By default the kernels run in interpret mode on CPU — relative numbers
only; the interesting derived number there is ref-vs-kernel agreement +
the work scaling, while absolute us/call is backend-specific. On a real
TPU, set ``REPRO_TPU=1`` to time the natively-compiled kernels instead
(pairwise MXU epilogue, segmented reductions, and the wavefront
traversal) — real-hardware numbers slot in without code changes. The
mode actually used is recorded in the artifact's ``kernels/mode`` record
so a baseline can never silently mix the two.

Emits the usual CSV lines plus a ``BENCH_kernels.json`` artifact (kernel
and reference timings per size) for the ``benchmarks.compare``
regression gate.
"""
from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref, segment
from benchmarks.common import benchmark_points, emit, timeit, write_artifact

# REPRO_TPU=1 opts into native compilation; anything else keeps the
# CPU-safe interpret path (also the right choice on a TPU host when you
# want apples-to-apples numbers against an interpret baseline).
NATIVE_TPU = os.environ.get("REPRO_TPU") == "1"
INTERPRET = not NATIVE_TPU


def _mode_record() -> dict:
    # seconds pinned at 0.0: compare never gates on this record, it only
    # documents how the numbers alongside it were produced.
    return {"seconds": 0.0, "interpret": INTERPRET, "native_tpu": NATIVE_TPU,
            "jax_backend": jax.default_backend()}


def _bench_pairwise(results: dict) -> None:
    rng = np.random.default_rng(0)
    for n, d in ((1024, 3), (1024, 64), (4096, 3)):
        x = jnp.asarray(rng.uniform(0, 1, (n, d)), jnp.float32)
        eps = 0.1
        t_ref = timeit(lambda: ref.pairwise_count_ref(x, x, eps * eps))
        t_k = timeit(lambda: ops.eps_neighbor_counts(x, x, eps,
                                                     interpret=INTERPRET))
        got = np.asarray(ops.eps_neighbor_counts(x, x, eps,
                                                 interpret=INTERPRET))
        want = np.asarray(ref.pairwise_count_ref(x, x, eps * eps))
        # pairs within ~1e-5 relative of eps are float knife-edges: the
        # kernel's expanded-form distance can round across the threshold.
        mismatch = int((got != want).sum())
        assert mismatch <= max(4, n // 1000), (n, d, mismatch)
        emit(f"kernel_pairwise_count_n{n}_d{d}", t_k,
             f"ref_us={t_ref * 1e6:.1f};knife_edge_rows={mismatch}")
        results[f"kernels/pairwise_count_n{n}_d{d}"] = {
            "seconds": t_k, "n": n, "d": d,
            "ref_seconds": t_ref, "knife_edge_rows": mismatch}


def _bench_segment(results: dict) -> None:
    rng = np.random.default_rng(1)
    for n, nseg in ((4096, 64),):
        seg = np.sort(rng.integers(0, nseg, n)).astype(np.int32)
        data = jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)
        seg = jnp.asarray(seg)
        t_k = timeit(lambda: segment.segment_sum_sorted(
            data, seg, nseg, interpret=INTERPRET))
        t_ref = timeit(lambda: ref.segment_sum_sorted_ref(data, seg, nseg))
        got = np.asarray(segment.segment_sum_sorted(data, seg, nseg,
                                                    interpret=INTERPRET))
        want = np.asarray(ref.segment_sum_sorted_ref(data, seg, nseg))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        emit(f"kernel_segment_sum_n{n}_s{nseg}", t_k,
             f"ref_us={t_ref * 1e6:.1f}")
        results[f"kernels/segment_sum_n{n}_s{nseg}"] = {
            "seconds": t_k, "n": n, "segments": nseg, "ref_seconds": t_ref}


def _bench_wavefront(results: dict) -> None:
    from repro.core.bvh import build_bvh
    from repro.core.geometry import scene_bounds
    from repro.core.query import query_count, within

    n = 1024
    pts, eps = benchmark_points(n)
    jp = jnp.asarray(pts)
    lo, hi = scene_bounds(jp)
    bvh = build_bvh(jp, lo, hi)
    pred = within(jp, eps)
    # The engine picks interpret-vs-native from the backend (kernels.ops.
    # INTERPRET); under REPRO_TPU=1 on a TPU host that IS native — the mode
    # record above documents which one this run measured.
    t_k = timeit(lambda: query_count(bvh, pred, backend="pallas",
                                     sort_queries=True), iters=2)
    t_ref = timeit(lambda: query_count(bvh, pred, backend="stackless",
                                       sort_queries=True), iters=2)
    emit(f"kernel_wavefront_count_n{n}", t_k, f"ref_us={t_ref * 1e6:.1f}")
    results[f"kernels/wavefront_count_n{n}"] = {
        "seconds": t_k, "n": n, "ref_seconds": t_ref}


def main(out_path: str = "BENCH_kernels.json") -> None:
    results: dict = {"kernels/mode": _mode_record()}
    _bench_pairwise(results)
    _bench_segment(results)
    _bench_wavefront(results)
    write_artifact(out_path, results)


if __name__ == "__main__":
    main()
