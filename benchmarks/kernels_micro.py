"""Microbenchmarks for the Pallas kernels (interpret mode on CPU — relative
numbers only; the kernels' target is the TPU MXU) and their jnp references.
The interesting derived number on CPU is ref-vs-kernel agreement + the work
scaling; absolute us/call is backend-specific.

Emits the usual CSV lines plus a ``BENCH_kernels.json`` artifact (kernel and
reference timings per size) for the ``benchmarks.compare`` regression gate.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels import ops, ref
from benchmarks.common import emit, timeit, write_artifact


def main(out_path: str = "BENCH_kernels.json") -> None:
    rng = np.random.default_rng(0)
    results: dict = {}
    for n, d in ((1024, 3), (1024, 64), (4096, 3)):
        x = jnp.asarray(rng.uniform(0, 1, (n, d)), jnp.float32)
        eps = 0.1
        t_ref = timeit(lambda: ref.pairwise_count_ref(x, x, eps * eps))
        t_k = timeit(lambda: ops.eps_neighbor_counts(x, x, eps))
        got = np.asarray(ops.eps_neighbor_counts(x, x, eps))
        want = np.asarray(ref.pairwise_count_ref(x, x, eps * eps))
        # pairs within ~1e-5 relative of eps are float knife-edges: the
        # kernel's expanded-form distance can round across the threshold.
        mismatch = int((got != want).sum())
        assert mismatch <= max(4, n // 1000), (n, d, mismatch)
        emit(f"kernel_pairwise_count_n{n}_d{d}", t_k,
             f"ref_us={t_ref * 1e6:.1f};knife_edge_rows={mismatch}")
        results[f"kernels/pairwise_count_n{n}_d{d}"] = {
            "seconds": t_k, "n": n, "d": d,
            "ref_seconds": t_ref, "knife_edge_rows": mismatch}
    write_artifact(out_path, results)


if __name__ == "__main__":
    main()
