"""Figure 4 reproduction: the DBSCAN improvement ladder on the benchmark
problem (minPts=2, ε = 0.168·(V/n)^{1/3}).

Paper milestones -> our variants:
  (1) initial adjacency-graph + CC          -> dbscan_graph_cc
  (2) FDBSCAN + callbacks (fused, O(n))     -> fdbscan, stack traversal, 32-bit
  (2b) + early termination (§4.1.2)         -> early_stop=True
  (4) stackless (rope) traversal            -> use_stack=False
  (6) 64-bit Morton codes                   -> use_64bit=True
  (7) pair traversal                        -> fdbscan_pair
  (8) FDBSCAN-DenseBox                      -> fdbscan_densebox
  (+) TPU-native tiled grid (beyond paper)  -> fdbscan_grid

(3) Karras->Apetrei construction is not separable here: the JAX build uses
closed-form range+rope construction (DESIGN.md §2), equivalent to Apetrei
with recovered Karras ordering. Paper's net improvement over the ladder:
~9.2x; exact per-step ratios differ on CPU vs A100 — the LADDER ORDER is
the reproduced claim.
"""
from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

from repro.core.dbscan import (dbscan_graph_cc, fdbscan, fdbscan_densebox,
                               fdbscan_pair)
from repro.core.fdbscan_grid import fdbscan_grid, grid_dims_for
from benchmarks.common import benchmark_points, emit, timeit, write_artifact

MIN_PTS = 2


def ladder(n: int = 4096, out_path: str = "BENCH_fig4.json"):
    pts, eps = benchmark_points(n)
    jp = jnp.asarray(pts)

    variants = [
        ("fig4_1_graph_cc", lambda: dbscan_graph_cc(jp, eps, MIN_PTS,
                                                    neighbor_capacity=512,
                                                    use_64bit=False)),
        ("fig4_2_fdbscan_stack_noes", lambda: fdbscan(
            jp, eps, MIN_PTS, use_stack=True, early_stop=False, use_64bit=False)),
        ("fig4_2b_fdbscan_stack_es", lambda: fdbscan(
            jp, eps, MIN_PTS, use_stack=True, early_stop=True, use_64bit=False)),
        ("fig4_4_stackless", lambda: fdbscan(
            jp, eps, MIN_PTS, use_stack=False, early_stop=True, use_64bit=False)),
        ("fig4_6_64bit", lambda: fdbscan(
            jp, eps, MIN_PTS, use_stack=False, early_stop=True, use_64bit=True)),
        ("fig4_7_pair", lambda: fdbscan_pair(jp, eps, MIN_PTS, edge_capacity=8)),
        ("fig4_8_densebox", lambda: fdbscan_densebox(jp, eps, MIN_PTS)),
    ]
    dims = grid_dims_for(np.zeros(3), np.ones(3), eps)
    cap = 256
    # The TPU-native grid runs the Pallas kernels in INTERPRET mode on CPU:
    # per-grid-step Python dispatch makes large stencil grids infeasible here
    # (on the TPU target the (cells x 27) grid is the fast path). Include it
    # in the ladder only when the interpreted grid is small enough.
    results: dict = {}
    if np.prod(dims) <= 4096:
        variants.append((
            "fig4_tpu_grid",
            lambda: fdbscan_grid(jp, eps, MIN_PTS,
                                 scene_lo=np.zeros(3, np.float32),
                                 grid_dims=dims, capacity=cap)))
    else:
        emit("fig4_tpu_grid", 0.0,
             f"skipped_on_cpu_interpret(cells={int(np.prod(dims))});"
             "validated vs faithful tier in tests/test_fdbscan_grid.py")
        # "seconds": 0.0 marks a timing record compare skips (ref > 0 band)
        # rather than an exact-match contract.
        results[f"fig4/tpu_grid_n{n}"] = {
            "seconds": 0.0, "n": n, "skipped": "cpu_interpret",
            "cells": int(np.prod(dims))}

    times = {}
    labels = {}
    for name, fn in variants:
        t = timeit(lambda fn=fn: fn(), iters=2)
        times[name] = t
        res = fn()
        if not hasattr(res, "labels"):      # fdbscan_grid: (result, overflow)
            res = res[0]
        labels[name] = res.labels
        base = times["fig4_1_graph_cc"]
        emit(name, t, f"n={n};speedup_vs_initial={base / t:.2f}x")
        results[f"fig4/{name.removeprefix('fig4_')}_n{n}"] = {
            "seconds": t, "n": n, "speedup_vs_initial": round(base / t, 2)}

    # all variants agree on the clustering (partition equality on cores)
    from repro.core.ref_numpy import labels_equivalent, core_mask_ref
    core = core_mask_ref(pts, eps, MIN_PTS)
    ref = np.asarray(labels["fig4_6_64bit"])
    for name, lab in labels.items():
        ok = labels_equivalent(np.asarray(lab), ref, core)
        assert ok, f"{name} disagrees with the ladder reference"
    # End-to-end = initial vs the best variant. Mirrors the paper: "FDBSCAN
    # became the faster one for this problem with the introduction of the
    # pair traversal" — DenseBox's inner cell scans are additionally slow on
    # the CPU-interpret substrate (no SIMT; vmapped while-loops).
    best = min((t, n) for n, t in times.items() if n != "fig4_1_graph_cc")
    total = times["fig4_1_graph_cc"] / best[0]
    emit("fig4_total_speedup", 0.0,
         f"ladder_end_to_end={total:.2f}x(best={best[1]});paper=9.2x")
    results[f"fig4/total_speedup_n{n}"] = {
        "seconds": 0.0, "n": n, "ladder_end_to_end": round(total, 2),
        "best_variant": best[1], "paper_speedup": 9.2}
    write_artifact(out_path, results)
    return times


def main() -> None:
    ladder()


if __name__ == "__main__":
    main()
