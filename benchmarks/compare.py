"""Benchmark regression gate (the ReFrame pattern: measured value vs. a
stored reference with a tolerance band, fail the run on violation).

Compares the ``BENCH_*.json`` artifacts the suites emit against committed
baselines in ``benchmarks/baselines/`` and FAILS (exit 1) when any timing
regresses by more than ``--tolerance`` (default 20%). Non-timing entries
(host-sync counts, staging words) are checked for exact equality — they are
part of the protocol contract, not noise.

  PYTHONPATH=src python -m benchmarks.compare BENCH_query.json ...
  PYTHONPATH=src python -m benchmarks.compare --update BENCH_*.json

``--update`` rewrites the baselines from the current artifacts (run it on the
reference machine after an intended perf change). Artifacts with no baseline
yet are reported and skipped (or adopted under ``--update``).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

BASELINES = pathlib.Path(__file__).resolve().parent / "baselines"


def _timings(tree: dict) -> dict:
    """name -> seconds for every entry carrying a ``seconds`` field."""
    return {name: rec["seconds"] for name, rec in tree.items()
            if isinstance(rec, dict) and isinstance(rec.get("seconds"),
                                                    (int, float))}


def _contracts(tree: dict) -> dict:
    """Entries with no timing: exact-match protocol facts."""
    return {name: rec for name, rec in tree.items()
            if not (isinstance(rec, dict) and "seconds" in rec)}


def compare_artifact(artifact: pathlib.Path, baseline: pathlib.Path,
                     tolerance: float) -> list[str]:
    cur = json.loads(artifact.read_text())
    base = json.loads(baseline.read_text())
    problems = []
    base_t, cur_t = _timings(base), _timings(cur)
    for name, ref in sorted(base_t.items()):
        if name not in cur_t:
            problems.append(f"{name}: present in baseline, missing from run")
            continue
        got = cur_t[name]
        if ref > 0 and got > ref * (1.0 + tolerance):
            problems.append(f"{name}: {got * 1e6:.0f}us vs baseline "
                            f"{ref * 1e6:.0f}us (+{(got / ref - 1) * 100:.0f}%"
                            f" > +{tolerance * 100:.0f}%)")
    for name, ref in sorted(_contracts(base).items()):
        got = _contracts(cur).get(name)
        if got != ref:
            problems.append(f"{name}: contract changed {ref!r} -> {got!r}")
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+", help="BENCH_*.json files")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional slowdown before failing")
    ap.add_argument("--baselines", default=str(BASELINES))
    ap.add_argument("--update", action="store_true",
                    help="adopt the current artifacts as the new baselines")
    args = ap.parse_args(argv)

    bdir = pathlib.Path(args.baselines)
    failed = False
    for art in map(pathlib.Path, args.artifacts):
        if not art.exists():
            print(f"MISSING artifact {art}")
            failed = True
            continue
        ref = bdir / art.name
        if args.update:
            bdir.mkdir(parents=True, exist_ok=True)
            ref.write_text(art.read_text())
            print(f"updated baseline {ref}")
            continue
        if not ref.exists():
            print(f"no baseline for {art.name} (run with --update to adopt)")
            continue
        problems = compare_artifact(art, ref, args.tolerance)
        for p in problems:
            print(f"REGRESSION {art.name}: {p}")
        if problems:
            failed = True
        else:
            print(f"ok {art.name}: {len(_timings(json.loads(art.read_text())))}"
                  f" timings within +{args.tolerance * 100:.0f}%")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
