"""Roofline report: flops / bytes / arithmetic intensity per kernel, from
XLA's own cost model (ERT-style, ROADMAP item 5).

"Fast as the hardware allows" must be a measured claim, not a vibe. For
each representative program of the kernel suites (``kernels_micro``'s
Pallas pairwise kernel + its jnp reference, ``query_micro``'s traversal
protocols), this report:

* AOT-compiles the jitted program (``jit(fn).lower(args).compile()``),
* reads XLA's ``cost_analysis()`` (flops and bytes as the compiler costs
  them — NOTE: XLA counts while-loop bodies once, so traversal-loop
  programs are lower bounds),
* re-walks the optimized HLO text with the loop-aware walker in
  ``repro.launch.hlo_cost`` (trip-count-multiplied flops/traffic and
  collective bytes),
* times the compiled program and derives achieved GFLOP/s, GB/s and
  arithmetic intensity (flops per byte — the roofline x-axis).

Emits CSV lines plus ``BENCH_roofline.json``. Every derived number lives
inside a record that carries ``seconds``, so ``benchmarks.compare`` bands
only the timing and treats the model-derived columns as informational.

  PYTHONPATH=src python -m benchmarks.roofline_report [--fast]
"""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import benchmark_points, emit, timeit, write_artifact
from repro.launch.hlo_cost import analyze_hlo


def _xla_cost(compiled) -> dict:
    """``cost_analysis()`` normalized across JAX versions (dict on new
    versions, list-of-dicts per device program on older ones)."""
    try:
        ca = compiled.cost_analysis()
    except Exception:  # noqa: BLE001 — absent on some backends
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _roofline_case(name: str, fn, args, results: dict) -> None:
    compiled = jax.jit(fn).lower(*args).compile()
    t = timeit(lambda: compiled(*args), iters=2)

    xla = _xla_cost(compiled)
    xla_flops = float(xla.get("flops", 0.0))
    xla_bytes = float(xla.get("bytes accessed", 0.0))
    try:
        hlo = analyze_hlo(compiled.as_text())
    except Exception:  # noqa: BLE001 — keep the timing even if parsing fails
        hlo = {"flops": 0.0, "traffic": 0.0, "coll": {"total": 0.0}}

    # Prefer the loop-aware walker for the ratio axes; fall back to XLA's
    # single-iteration numbers when the walker sees no dot/memory ops.
    flops = hlo["flops"] or xla_flops
    bytes_ = hlo["traffic"] or xla_bytes
    ai = flops / bytes_ if bytes_ else 0.0
    results[name] = {
        "seconds": t,
        "xla_flops": xla_flops,
        "xla_bytes": xla_bytes,
        "hlo_flops": float(hlo["flops"]),
        "hlo_bytes": float(hlo["traffic"]),
        "coll_bytes": float(hlo["coll"]["total"]),
        "ai_flops_per_byte": ai,
        "gflops_per_s": flops / t / 1e9 if t else 0.0,
        "gbytes_per_s": bytes_ / t / 1e9 if t else 0.0,
    }
    emit(name, t,
         derived=f"ai={ai:.3f}flops/B;gflops={flops / max(t, 1e-12) / 1e9:.2f};"
                 f"gbytes={bytes_ / max(t, 1e-12) / 1e9:.2f}")


def _query_cases(fast: bool, results: dict) -> None:
    from repro.core.bvh import build_bvh
    from repro.core.geometry import scene_bounds
    from repro.core.query import (query_count, query_csr_device, within)

    n = 512 if fast else 4096
    pts, eps = benchmark_points(n)
    jp = jnp.asarray(pts)
    lo, hi = scene_bounds(jp)
    bvh = build_bvh(jp, lo, hi)
    max_count = int(jnp.max(query_count(bvh, within(jp, eps))))
    cap = n * (1 << max(1, int(np.ceil(np.log2(max(max_count, 2))))))

    # pallas = the wavefront kernel program; XLA's cost model sees the
    # pallas_call as one fused launch, so its flops/bytes reflect the
    # staging around the kernel — the row tracks launch + padding cost.
    for backend in ("stackless", "stack", "pallas"):
        _roofline_case(
            f"roofline/query_count_{backend}_n{n}",
            lambda p, b=backend: query_count(bvh, within(p, eps), backend=b),
            (jp,), results)
    _roofline_case(
        f"roofline/query_csr_device_n{n}",
        lambda p: query_csr_device(bvh, within(p, eps), cap).indices,
        (jp,), results)


def _kernel_cases(fast: bool, results: dict) -> None:
    from repro.kernels import ops, ref

    n, d = (256, 3) if fast else (1024, 64)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, (n, d)), jnp.float32)
    _roofline_case(f"roofline/kernel_pairwise_n{n}_d{d}",
                   lambda a: ops.eps_neighbor_counts(a, a, 0.1), (x,), results)
    _roofline_case(f"roofline/ref_pairwise_n{n}_d{d}",
                   lambda a: ref.pairwise_count_ref(a, a, 0.01), (x,), results)


def main(fast: bool = False, out_path: str = "BENCH_roofline.json") -> None:
    results: dict = {}
    _kernel_cases(fast, results)
    _query_cases(fast, results)
    write_artifact(out_path, results)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.fast)
