"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
JSONs in results/dryrun/."""
from __future__ import annotations

import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load(mesh_kind: str = "single") -> list[dict]:
    rows = []
    for p in sorted(RESULTS.glob(f"*__{mesh_kind}.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def render(rows: list[dict]) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant "
           "| mem/dev (GB) | fits | useful/HLO | MFU bound |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        rf = r["roofline"]
        mm = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rf['t_compute_s'] * 1e3:.1f} | {rf['t_memory_s'] * 1e3:.1f} "
            f"| {rf['t_collective_s'] * 1e3:.1f} | {rf['dominant']} "
            f"| {mm['total_per_dev'] / 1e9:.2f} | {'Y' if mm['fits_16GB'] else 'N'} "
            f"| {rf['useful_flops_ratio']:.2f} "
            f"| {rf['mfu_bound']:.3f} |" if rf["useful_flops_ratio"] else
            f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | - | - |")
    return "\n".join(lines)


def main() -> None:
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    rows = load(mesh)
    if not rows:
        print(f"no dry-run results for mesh={mesh} in {RESULTS}")
        return
    print(render(rows))


if __name__ == "__main__":
    main()
