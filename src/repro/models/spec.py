"""Parameter-spec machinery: one source of truth for shapes, logical axes,
and initializers.

Every model module builds a pytree of ``TensorSpec`` leaves. From that one
tree we derive:

* ``init_params``    — materialized parameters (real training / smoke tests)
* ``abstract_params``— ``jax.ShapeDtypeStruct`` stand-ins (dry-run: no
                       allocation at any size, the shannon/kernels pattern)
* ``param_pspecs``   — ``PartitionSpec`` per leaf via the sharding rules
                       (repro.parallel.sharding)

Logical axis names (mapped to mesh axes by ``repro/parallel/sharding.py``):
  "embed"   — d_model rows (FSDP-sharded)
  "mlp"     — ffn hidden (TP)
  "heads"   — attention query heads (TP)
  "kv"      — kv heads (TP when divisible)
  "qkv"     — fused per-head feature dim (never sharded)
  "vocab"   — vocabulary (TP)
  "experts" — MoE expert dim (EP)
  "layers"  — scan-stacked layer dim (never sharded; pipeline later)
  None      — replicated
"""
from __future__ import annotations

import hashlib
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class TensorSpec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"      # normal | zeros | ones | embed
    scale: float | None = None  # stddev override; default fan-in

    def with_leading(self, n: int, axis_name: str | None = "layers") -> "TensorSpec":
        return TensorSpec((n,) + self.shape, (axis_name,) + self.axes, self.init, self.scale)


def is_spec(x: Any) -> bool:
    return isinstance(x, TensorSpec)


def _leaf_key(key: jax.Array, path) -> jax.Array:
    h = int.from_bytes(hashlib.md5(jax.tree_util.keystr(path).encode()).digest()[:4], "big")
    return jax.random.fold_in(key, h)


def _init_leaf(spec: TensorSpec, key: jax.Array, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 1.0
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    # fan-in normal: last axis is the output dim by our convention (in, out)
    fan_in = int(np.prod(spec.shape[:-1])) if len(spec.shape) > 1 else spec.shape[0]
    std = spec.scale if spec.scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_params(spec_tree, key: jax.Array, dtype=jnp.float32):
    """Materialize parameters. Deterministic per-leaf keys from tree paths."""
    return jax.tree_util.tree_map_with_path(
        lambda path, s: _init_leaf(s, _leaf_key(key, path), dtype),
        spec_tree, is_leaf=is_spec)


def abstract_params(spec_tree, dtype=jnp.float32):
    """ShapeDtypeStruct tree — dry-run stand-ins, no memory allocated."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree, is_leaf=is_spec)


def param_axes(spec_tree):
    return jax.tree_util.tree_map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def count_params(spec_tree) -> int:
    leaves = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def stack_specs(spec_tree, n: int):
    """Add a leading scan-layer axis of size n to every leaf."""
    return jax.tree_util.tree_map(lambda s: s.with_leading(n), spec_tree, is_leaf=is_spec)
