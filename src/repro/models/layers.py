"""Shared neural-net primitives: norms, RoPE, embeddings, activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.spec import TensorSpec


def rmsnorm_spec(d: int) -> TensorSpec:
    return TensorSpec((d,), (None,), init="ones")


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)
