"""Dense FFN (SwiGLU / GELU) and the MoE FFN with capacity-based dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.spec import TensorSpec


# --- dense FFN --------------------------------------------------------------

def ffn_spec(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    spec = {
        "wi": TensorSpec((d, f), ("embed", "mlp")),
        "wo": TensorSpec((f, d), ("mlp", "embed")),
    }
    if cfg.activation in ("silu", "geglu"):  # gated (SwiGLU / GeGLU)
        spec["wg"] = TensorSpec((d, f), ("embed", "mlp"))
    return spec


def ffn(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    if cfg.activation in ("silu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = L.activate(g, "gelu" if cfg.activation == "geglu" else "silu") * h
    else:
        h = L.activate(h, "gelu")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))


# --- MoE FFN ----------------------------------------------------------------

def moe_spec(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    spec = {
        "router": TensorSpec((d, e), ("embed", None), scale=d ** -0.5),
        "wi": TensorSpec((e, d, f), ("experts", "embed", "mlp"), scale=d ** -0.5),
        "wg": TensorSpec((e, d, f), ("experts", "embed", "mlp"), scale=d ** -0.5),
        "wo": TensorSpec((e, f, d), ("experts", "mlp", "embed"), scale=f ** -0.5),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        spec["shared"] = ffn_spec(cfg, d_ff=fs)
    return spec


def moe_ffn(p: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """GShard-style grouped capacity dispatch. Tokens are split into groups
    of ``moe_group_size``; capacity is per (group, expert), so the dispatch
    one-hot is (G, Tg, E, C) — the largest MoE activation is the inherent
    k·cf·T·D expert input, never a T×E table. The group dim shards over the
    data axes and the expert dim over the EP ("model") axis; GSPMD inserts
    the dispatch all-to-alls. Returns (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_tok = b * s
    tg = min(cfg.moe_group_size, n_tok)
    assert n_tok % tg == 0, (n_tok, tg)
    g = n_tok // tg
    capacity = max(1, min(int(cfg.capacity_factor * tg * k / e), tg))
    tokens = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", tokens.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Buffer slot of each (token, choice) within its (group, expert).
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)    # (G, Tg, k, E)
    flat = onehot.reshape(g, tg * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, tg, k, e)
    pos = jnp.sum(pos * onehot, axis=-1)                       # (G, Tg, k)
    keep = pos < capacity

    # dispatch one-hot (G, Tg, k, E, C) -> summed over k to (G, Tg, E, C)
    disp = onehot.astype(x.dtype) * keep[..., None].astype(x.dtype)
    disp = disp[..., None] * jax.nn.one_hot(pos, capacity, dtype=x.dtype)[..., None, :]
    disp_te = disp.sum(2)                                      # (G, Tg, E, C)
    expert_in = jnp.einsum("gtec,gtd->gecd", disp_te, tokens)  # (G, E, C, D)

    h = jnp.einsum("gecd,edf->gecf", expert_in, p["wi"].astype(x.dtype))
    gt = jnp.einsum("gecd,edf->gecf", expert_in, p["wg"].astype(x.dtype))
    h = L.activate(gt, "silu") * h
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))

    combine = disp * gate_vals[..., None, None].astype(x.dtype)  # (G,Tg,k,E,C)
    out = jnp.einsum("gtkec,gecd->gtd", combine, expert_out)

    if cfg.n_shared_experts:
        out = out + ffn(p["shared"], cfg, tokens)

    # Load-balancing aux loss (Switch-style), averaged over groups.
    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = onehot.astype(jnp.float32).sum(2).mean(axis=(0, 1))   # routed fraction
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux
