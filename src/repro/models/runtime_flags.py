"""Process-wide lowering flags.

UNROLL_SCANS: XLA's cost_analysis does not multiply while-loop bodies by
their trip counts, so rolled scans undercount FLOPs/bytes/collectives. The
dry-run calibration pass sets this flag to lower with fully-unrolled scans
(at reduced layer counts) and extrapolates per-layer costs; production
lowering keeps scans rolled (compile time, HLO size).

The sequential sLSTM time scan is NEVER unrolled (4096-step bodies); its
FLOPs are corrected analytically in the dry-run (see dryrun.slstm_flops).
"""
UNROLL_SCANS = False


def scan_unroll(length: int) -> int | bool:
    return length if UNROLL_SCANS else 1
