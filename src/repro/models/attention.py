"""GQA/MQA/MHA attention with RoPE, sliding windows, softcapping, QK-norm,
cross-attention, and KV caches for prefill/decode.

KV cache contract (decode): cache holds ``S`` past tokens; the new token is
written at ``pos % S`` and attends to every cached position ``<= pos`` (ring
semantics; for the assigned decode shapes pos == S so the full cache is
live). The cache layout (B, S, n_kv, hd) is sharded batch-over-data and
seq-over-model (SP-decode, DESIGN.md §6) — head counts (8, 10, 1, ...) are
rarely divisible by the model axis, sequence always is.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.spec import TensorSpec

NEG_INF = -2.0 ** 30  # large-but-finite; keeps softmax NaN-free on full masks

# Long sequences use blockwise (flash-style) attention: (S, S) scores never
# materialize; tiles are (Q_CHUNK, KV_CHUNK) with online-softmax carry.
CHUNKED_THRESHOLD = 8192
Q_CHUNK = 1024
KV_CHUNK = 4096


class KvCache(NamedTuple):
    k: jax.Array  # (B, S, n_kv, hd)
    v: jax.Array  # (B, S, n_kv, hd)


def attn_spec(cfg: ModelConfig, cross: bool = False) -> dict:
    """Cross-attention K/V consume the memory stream, which is always
    pre-projected to d_model (frontend_proj / encoder output)."""
    del cross
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    spec = {
        "wq": TensorSpec((d, h, hd), ("embed", "heads", "qkv")),
        "wk": TensorSpec((d, kv, hd), ("embed", "kv", "qkv")),
        "wv": TensorSpec((d, kv, hd), ("embed", "kv", "qkv")),
        "wo": TensorSpec((h, hd, d), ("heads", "qkv", "embed")),
    }
    if cfg.attn_bias:
        spec["bq"] = TensorSpec((h, hd), ("heads", "qkv"), init="zeros")
        spec["bk"] = TensorSpec((kv, hd), ("kv", "qkv"), init="zeros")
        spec["bv"] = TensorSpec((kv, hd), ("kv", "qkv"), init="zeros")
    if cfg.qk_norm:
        spec["q_norm"] = L.rmsnorm_spec(hd)
        spec["k_norm"] = L.rmsnorm_spec(hd)
    return spec


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array, kv_input: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", kv_input, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", kv_input, p["wv"].astype(x.dtype))
    if cfg.attn_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _sdpa(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
          mask: jax.Array | None) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, T, KV, hd); mask: (B|1, S, T) bool or None.

    GQA k/v are broadcast to full query heads BEFORE the score einsum: the
    (kv, rep) grouped layout makes the (S, S) score tensor unshardable when
    kv < mesh model-axis (it replicates and blows HBM). Full-head scores
    shard over heads or query-seq — `constrain_scores` picks per mesh."""
    from repro.parallel.sharding import constrain_scores
    b, s, h, hd = q.shape
    t, n_kv = k.shape[1], k.shape[2]
    rep = h // n_kv
    if rep > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, n_kv, rep, hd)).reshape(b, t, h, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :], (b, t, n_kv, rep, hd)).reshape(b, t, h, hd)
    scores = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = L.softcap(scores, cfg.attn_softcap)
    if mask is not None:
        scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    scores = constrain_scores(scores, decode=s == 1)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthk->bshk", probs, v)
    return out


def _sdpa_chunked(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
                  *, window: int | None, causal: bool) -> jax.Array:
    """Blockwise attention with online softmax (XLA-level flash attention).

    Outer scan over query chunks; inner scan over a bounded span of KV
    chunks (the full prefix for global causal — masked tiles included, a
    documented ~2x attention-FLOP overcount for causal prefill — or
    window//KV_CHUNK + 2 chunks for sliding-window layers)."""
    from repro.parallel.sharding import constrain_scores
    b, s, h, hd = q.shape
    t, n_kv = k.shape[1], k.shape[2]
    rep = h // n_kv
    if rep > 1:
        k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, n_kv, rep, hd)).reshape(b, t, h, hd)
        v = jnp.broadcast_to(v[:, :, :, None, :], (b, t, n_kv, rep, hd)).reshape(b, t, h, hd)
    qc = min(Q_CHUNK, s)
    kc = min(KV_CHUNK, t)
    n_q = s // qc
    n_k = t // kc
    assert s % qc == 0 and t % kc == 0, (s, t)
    span = n_k if (window is None or not causal) \
        else min(n_k, window // kc + 2)
    scale = hd ** -0.5

    q_ = q.reshape(b, n_q, qc, h, hd).swapaxes(0, 1)       # (n_q, B, qc, H, hd)

    def q_step(_, xs):
        qi, q_chunk = xs                                    # q_chunk (B,qc,H,hd)
        q_lo = qi * qc

        def kv_step(carry, jj):
            m_run, l_run, acc = carry
            # chunk index: the trailing `span` chunks ending at the diagonal;
            # out-of-range chunks are fully masked (clipped slice, dead tile).
            kj_raw = (qi - span + 1 + jj) if causal else jj
            kj = jnp.clip(kj_raw, 0, n_k - 1)
            valid = (kj_raw >= 0) & (kj_raw <= (qi if causal else n_k - 1))
            k_lo = kj * kc
            k_chunk = jax.lax.dynamic_slice(
                k, (0, k_lo, 0, 0), (b, kc, h, hd))
            v_chunk = jax.lax.dynamic_slice(
                v, (0, k_lo, 0, 0), (b, kc, h, hd))
            scores = jnp.einsum("bshk,bthk->bhst", q_chunk, k_chunk)
            scores = scores.astype(jnp.float32) * scale
            scores = L.softcap(scores, cfg.attn_softcap)
            qpos = q_lo + jnp.arange(qc)[:, None]
            kpos = k_lo + jnp.arange(kc)[None, :]
            live = jnp.broadcast_to(valid, (qc, kc))
            if causal:
                live &= kpos <= qpos
            if window is not None:
                live &= kpos > qpos - window
            scores = jnp.where(live[None, None], scores, -jnp.inf)
            scores = constrain_scores(scores)
            m_new = jnp.maximum(m_run, scores.max(-1))
            # -inf guards: rows with no live key yet must contribute 0.
            safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(scores - safe_m[..., None])          # exp(-inf) = 0
            corr = jnp.where(jnp.isfinite(m_run),
                             jnp.exp(m_run - safe_m), 0.0)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhst,bthk->bhsk", p.astype(q.dtype), v_chunk).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(span))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return None, out.swapaxes(1, 2)                     # (B, qc, H, hd)

    q_step = jax.checkpoint(q_step)
    _, outs = jax.lax.scan(q_step, None, (jnp.arange(n_q), q_))
    return outs.swapaxes(0, 1).reshape(b, s, h, hd)


def _causal_mask(s: int, window: int | None, q_offset: int = 0) -> jax.Array:
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(s + q_offset)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None]  # (1, S, S+off)


def self_attention(p: dict, cfg: ModelConfig, x: jax.Array, *,
                   positions: jax.Array, window: int | None,
                   cache: KvCache | None = None,
                   cache_pos: jax.Array | None = None,
                   causal: bool = True):
    """Returns (out, new_cache). Modes:
      train/prefill: full sequence, causal (or bidirectional for encoders);
                     returns the (B, S, kv, hd) cache when cache is None.
      decode:        x is (B, 1, D); cache holds S past tokens.
    """
    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    q = L.rope(q, positions, cfg.rope_theta)
    k_new = L.rope(k_new, positions, cfg.rope_theta)

    if cache is None:  # train / prefill
        s = x.shape[1]
        if s >= CHUNKED_THRESHOLD:
            out = _sdpa_chunked(cfg, q, k_new, v_new, window=window,
                                causal=causal)
        else:
            mask = _causal_mask(s, window) if causal else None
            out = _sdpa(cfg, q, k_new, v_new, mask)
        new_cache = KvCache(k=k_new, v=v_new)
    else:  # decode: single new token at absolute position cache_pos
        s_cache = cache.k.shape[1]
        slot = (cache_pos % s_cache).astype(jnp.int32)
        k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
        kpos = jnp.arange(s_cache)[None, :]
        live = kpos <= cache_pos
        if window is not None:
            live &= kpos > cache_pos - window
        out = _sdpa(cfg, q, k, v, live[:, None, :])
        new_cache = KvCache(k=k, v=v)

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


def cross_attention(p: dict, cfg: ModelConfig, x: jax.Array,
                    memory_kv: KvCache) -> jax.Array:
    """Cross-attention to precomputed encoder/frontend K,V (no mask)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
    out = _sdpa(cfg, q, memory_kv.k, memory_kv.v, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def encode_memory(p: dict, cfg: ModelConfig, memory: jax.Array) -> KvCache:
    """Project encoder output / modality-frontend embeddings to cross K,V."""
    k = jnp.einsum("btd,dhk->bthk", memory, p["wk"].astype(memory.dtype))
    v = jnp.einsum("btd,dhk->bthk", memory, p["wv"].astype(memory.dtype))
    if cfg.attn_bias:
        k = k + p["bk"].astype(memory.dtype)
        v = v + p["bv"].astype(memory.dtype)
    if cfg.qk_norm:
        k = L.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return KvCache(k=k, v=v)
