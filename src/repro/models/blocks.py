"""Sublayer/group assembly: every architecture is n_groups repeats of a
block_pattern of sublayers, scanned with remat (MaxText-style stacked
layers). Heterogeneous patterns (gemma2 local/global, jamba attn:mamba 1:7,
vision cross-attn every 5th) live entirely in the pattern."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mlp as M
from repro.models import ssm as S
from repro.models.spec import TensorSpec

Cache = Any  # pytree per sublayer; {} when stateless in decode


def _ffn_part_spec(cfg: ModelConfig, kind: str, layer_in_group: int) -> dict:
    """FFN spec attached to a sublayer: dense, MoE, or none (d_ff == 0)."""
    if kind.endswith("_moe"):
        return {"moe": M.moe_spec(cfg)}
    if cfg.d_ff > 0:
        return {"ffn": M.ffn_spec(cfg)}
    return {}


def sublayer_spec(cfg: ModelConfig, kind: str, layer_in_group: int = 0) -> dict:
    d = cfg.d_model
    spec: dict = {"norm1": L.rmsnorm_spec(d)}
    base = kind.removesuffix("_moe")
    if base in ("attn", "attn_local"):
        spec["attn"] = A.attn_spec(cfg)
    elif base == "cross":
        spec["attn"] = A.attn_spec(cfg, cross=True)
    elif base == "mamba":
        spec["mamba"] = S.mamba_spec(cfg)
    elif base == "mlstm":
        spec["mlstm"] = S.mlstm_spec(cfg)
    elif base == "slstm":
        spec["slstm"] = S.slstm_spec(cfg)
    else:
        raise ValueError(kind)
    if cfg.sandwich_norm:
        spec["norm1_post"] = L.rmsnorm_spec(d)

    ffn_spec = _ffn_part_spec(cfg, kind, layer_in_group)
    if ffn_spec:
        spec["norm2"] = L.rmsnorm_spec(d)
        spec.update(ffn_spec)
        if cfg.sandwich_norm:
            spec["norm2_post"] = L.rmsnorm_spec(d)
    return spec


def sublayer_cache_shape(cfg: ModelConfig, kind: str, batch: int, cache_len: int):
    """Zero-initialized decode cache for one sublayer (shapes only matter)."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    h = cfg.n_heads
    di = cfg.ssm_expand * cfg.d_model
    base = kind.removesuffix("_moe")
    f32 = jnp.float32
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else f32
    if base in ("attn", "attn_local"):
        return {"k": ((batch, cache_len, kv, hd), act),
                "v": ((batch, cache_len, kv, hd), act)}
    if base == "cross":
        t = max(cfg.frontend_tokens, 1)
        return {"mk": ((batch, t, kv, hd), act),
                "mv": ((batch, t, kv, hd), act)}
    if base == "mamba":
        return {"state": ((batch, di, cfg.ssm_state), f32),
                "conv": ((batch, cfg.ssm_conv - 1, di), act)}
    if base == "mlstm":
        return {"C": ((batch, h, hd, hd), f32), "n": ((batch, h, hd), f32)}
    if base == "slstm":
        return {"h": ((batch, h, hd), f32), "c": ((batch, h, hd), f32),
                "n": ((batch, h, hd), f32)}
    raise ValueError(kind)


def sublayer_apply(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
                   ctx: dict, cache: dict | None):
    """Returns (x, new_cache, aux_loss). ctx keys: positions (B,S) or (B,1)
    absolute positions; mode; memory (B,T,D) for cross; cache_pos scalar."""
    base = kind.removesuffix("_moe")
    mode = ctx["mode"]
    aux = jnp.float32(0.0)
    new_cache: dict = {}

    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if mode != "decode" and x.shape[1] > 1:
        from repro.parallel.sharding import (constrain_attn_input,
                                             constrain_block_input)
        h = constrain_block_input(h)
        if base in ("attn", "attn_local", "cross"):
            h = constrain_attn_input(h)
    if base in ("attn", "attn_local"):
        window = cfg.sliding_window if base == "attn_local" else None
        if mode == "decode":
            kvc = A.KvCache(cache["k"], cache["v"])
            out, kvc2 = A.self_attention(
                p["attn"], cfg, h, positions=ctx["positions"], window=window,
                cache=kvc, cache_pos=ctx["cache_pos"])
            new_cache = {"k": kvc2.k, "v": kvc2.v}
        else:
            out, kvc2 = A.self_attention(
                p["attn"], cfg, h, positions=ctx["positions"], window=window,
                causal=ctx.get("causal", True))
            if mode == "prefill":  # write prompt K/V into the allocated cache
                z = (0, 0, 0, 0)
                new_cache = {
                    "k": jax.lax.dynamic_update_slice(
                        cache["k"], kvc2.k.astype(cache["k"].dtype), z),
                    "v": jax.lax.dynamic_update_slice(
                        cache["v"], kvc2.v.astype(cache["v"].dtype), z),
                }
    elif base == "cross":
        if mode == "decode":
            mem_kv = A.KvCache(cache["mk"], cache["mv"])
            new_cache = dict(cache)
        else:
            mem_kv = A.encode_memory(p["attn"], cfg, ctx["memory"])
            if mode == "prefill":
                new_cache = {"mk": mem_kv.k, "mv": mem_kv.v}
        out = A.cross_attention(p["attn"], cfg, h, mem_kv)
    elif base == "mamba":
        if mode == "decode":
            out, (st, cv) = S.mamba(p["mamba"], cfg, h, state=cache["state"],
                                    conv_state=cache["conv"])
            new_cache = {"state": st, "conv": cv}
        else:
            out, (st, cv) = S.mamba(p["mamba"], cfg, h)
            if mode == "prefill":
                new_cache = {"state": st, "conv": cv}
    elif base == "mlstm":
        if mode == "decode":
            out, (C, n) = S.mlstm(p["mlstm"], cfg, h, state=(cache["C"], cache["n"]))
            new_cache = {"C": C, "n": n}
        else:
            out, (C, n) = S.mlstm(p["mlstm"], cfg, h)
            if mode == "prefill":
                new_cache = {"C": C, "n": n}
    elif base == "slstm":
        if mode == "decode":
            out, (hs, cs, ns) = S.slstm(p["slstm"], cfg, h,
                                        state=(cache["h"], cache["c"], cache["n"]))
            new_cache = {"h": hs, "c": cs, "n": ns}
        else:
            out, (hs, cs, ns) = S.slstm(p["slstm"], cfg, h)
            if mode == "prefill":
                new_cache = {"h": hs, "c": cs, "n": ns}
    else:
        raise ValueError(kind)

    if cfg.sandwich_norm:
        out = L.rmsnorm(p["norm1_post"], out, cfg.norm_eps)
    x = x + out

    if kind.endswith("_moe") or "ffn" in p:
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if kind.endswith("_moe"):
            y, aux = M.moe_ffn(p["moe"], cfg, h2)
        else:
            y = M.ffn(p["ffn"], cfg, h2)
        if cfg.sandwich_norm:
            y = L.rmsnorm(p["norm2_post"], y, cfg.norm_eps)
        x = x + y
    return x, new_cache, aux


def group_spec(cfg: ModelConfig) -> dict:
    return {f"sub{i}_{kind}": sublayer_spec(cfg, kind, i)
            for i, kind in enumerate(cfg.block_pattern)}


def group_apply(cfg: ModelConfig, params: dict, x: jax.Array, ctx: dict,
                cache: dict | None):
    """Apply one pattern group. cache: {subkey: subcache} or None."""
    new_cache: dict = {}
    aux_total = jnp.float32(0.0)
    for i, kind in enumerate(cfg.block_pattern):
        key = f"sub{i}_{kind}"
        sub_cache = cache.get(key) if cache is not None else None
        x, nc, aux = sublayer_apply(cfg, kind, params[key], x, ctx, sub_cache)
        if nc:
            new_cache[key] = nc
        aux_total = aux_total + aux
    return x, new_cache, aux_total


def group_cache_shapes(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    shapes = {}
    for i, kind in enumerate(cfg.block_pattern):
        base = kind.removesuffix("_moe")
        if base in ("attn", "attn_local", "cross", "mamba", "mlstm", "slstm"):
            shapes[f"sub{i}_{kind}"] = sublayer_cache_shape(cfg, kind, batch, cache_len)
    return shapes
