"""Full models: decoder LM (all 10 archs), optional encoder (enc-dec), and
the train / prefill / decode entry points.

Layer stacking: parameters for the repeated block group are stacked on a
leading "layers" axis and consumed by ``lax.scan`` with full remat
(MaxText-style) — compile time is O(1) in depth and activation memory is
one group plus the per-group carry.

Loss is chunked over the sequence so (B, S, vocab) logits never materialize
(256k vocabularies at 4k tokens would be tens of GB otherwise).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import blocks as B
from repro.models import layers as L
from repro.models.spec import TensorSpec, stack_specs
from repro.parallel.sharding import constrain_activation
from repro.models import runtime_flags as rf

LOSS_CHUNK = 512


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------

def model_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    spec: dict = {
        # std 1/sqrt(d): tied logits land at O(1); gemma-style scale_embed
        # multiplies activations back up by sqrt(d).
        "embed": TensorSpec((cfg.padded_vocab, d), ("vocab", "embed"),
                            init="embed", scale=d ** -0.5),
        "layers": stack_specs(B.group_spec(cfg), cfg.n_groups),
        "final_norm": L.rmsnorm_spec(d),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = TensorSpec((d, cfg.padded_vocab), ("embed", "vocab"))
    if cfg.first_layer_dense_ff:  # deepseek: dense layer 0
        dense_cfg = cfg.scaled(block_pattern=("attn",), d_ff=cfg.first_layer_dense_ff,
                               n_experts=0)
        spec["layer0"] = B.group_spec(dense_cfg)
    if cfg.frontend_dim:
        spec["frontend_proj"] = TensorSpec((cfg.frontend_dim, d), (None, "embed"))
    if cfg.encoder_layers:
        enc_cfg = cfg.scaled(block_pattern=("attn",), n_experts=0)
        spec["encoder"] = {
            "layers": stack_specs(B.group_spec(enc_cfg), cfg.encoder_layers),
            "final_norm": L.rmsnorm_spec(d),
        }
    return spec


# ---------------------------------------------------------------------------
# Shared stack runner
# ---------------------------------------------------------------------------

def _scan_groups(cfg: ModelConfig, stacked_params, x, ctx, cache_stacked):
    """Scan the group stack. cache_stacked: pytree with leading n_groups axis
    (or None in train mode). Returns (x, new_cache_stacked, aux_sum)."""

    def body(carry, xs):
        h, aux = carry
        p_g, c_g = xs
        h, new_c, aux_g = B.group_apply(cfg, p_g, h, ctx, c_g)
        return (constrain_activation(h), aux + aux_g), new_c

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (stacked_params, cache_stacked),
        unroll=rf.scan_unroll(cfg.n_groups))
    return x, new_cache, aux


def _embed(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    h = params["embed"][tokens].astype(_dtype(cfg))
    if cfg.scale_embed:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def _mask_padded_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    if cfg.padded_vocab == cfg.vocab:
        return logits
    live = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(live, logits, A.NEG_INF)


def _logits(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype)).astype(jnp.float32)
    return _mask_padded_vocab(cfg, L.softcap(logits, cfg.final_softcap))


def _run_encoder(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Bidirectional encoder over frontend embeddings (B, T, frontend_dim)."""
    enc_cfg = cfg.scaled(block_pattern=("attn",), n_experts=0)
    h = jnp.einsum("btf,fd->btd", frames.astype(_dtype(cfg)),
                   params["frontend_proj"].astype(_dtype(cfg)))
    t = h.shape[1]
    pos = jnp.broadcast_to(jnp.arange(t)[None], h.shape[:2])
    ctx = {"mode": "train", "positions": pos, "causal": False}

    def body(carry, p_g):
        hh, _ = carry
        hh, _, _ = B.group_apply(enc_cfg, p_g, hh, ctx, None)
        return (hh, jnp.float32(0.0)), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (h, _), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), params["encoder"]["layers"],
                             unroll=rf.scan_unroll(cfg.encoder_layers))
    return L.rmsnorm(params["encoder"]["final_norm"], h, cfg.norm_eps)


def _memory(params, cfg: ModelConfig, batch: dict) -> jax.Array | None:
    """Cross-attention memory: encoder output (audio) or projected patch
    embeddings (vlm). The modality frontend itself is a stub per assignment."""
    if cfg.encoder_layers:
        return _run_encoder(params, cfg, batch["frames"])
    if cfg.frontend_dim:
        v = batch["vision"].astype(_dtype(cfg))
        return jnp.einsum("btf,fd->btd", v, params["frontend_proj"].astype(_dtype(cfg)))
    return None


def _run_stack(params, cfg: ModelConfig, h: jax.Array, ctx: dict, cache=None):
    aux0 = jnp.float32(0.0)
    if "layer0" in params:  # deepseek dense first layer (not scanned)
        dense_cfg = cfg.scaled(block_pattern=("attn",), d_ff=cfg.first_layer_dense_ff,
                               n_experts=0)
        c0 = cache["layer0"] if cache is not None else None
        h, c0_new, _ = B.group_apply(dense_cfg, params["layer0"], h, ctx, c0)
    else:
        c0_new = None
    stacked_cache = cache["layers"] if cache is not None else None
    if stacked_cache is None:
        # Train mode: scan without cache xs -> feed per-group empty pytrees.
        def body(carry, p_g):
            hh, aux = carry
            hh, _, aux_g = B.group_apply(cfg, p_g, hh, ctx, None)
            return (constrain_activation(hh), aux + aux_g), None

        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        (h, aux), _ = jax.lax.scan(body, (h, aux0), params["layers"],
                                   unroll=rf.scan_unroll(cfg.n_groups))
        new_cache = None
    else:
        h, new_stacked, aux = _scan_groups(cfg, params["layers"], h, ctx, stacked_cache)
        new_cache = {"layers": new_stacked}
        if c0_new is not None:
            new_cache["layer0"] = c0_new
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def _chunked_xent(params, cfg: ModelConfig, h: jax.Array, labels: jax.Array,
                  mask: jax.Array) -> jax.Array:
    """Mean token cross-entropy without materializing full logits."""
    b, s, d = h.shape
    c = min(LOSS_CHUNK, s)
    n_chunks = s // c
    assert s % c == 0
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    def chunk(carry, xs):
        hx, lx, mx = xs                                        # (B,c,*)
        logits = jnp.einsum("bsd,dv->bsv", hx, w.astype(hx.dtype)).astype(jnp.float32)
        logits = _mask_padded_vocab(cfg, L.softcap(logits, cfg.final_softcap))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - gold) * mx)
        return carry + loss, None

    chunk = jax.checkpoint(chunk)
    hs = h.reshape(b, n_chunks, c, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunks, c).swapaxes(0, 1)
    ms = mask.reshape(b, n_chunks, c).swapaxes(0, 1).astype(jnp.float32)
    total, _ = jax.lax.scan(chunk, jnp.float32(0.0), (hs, ls, ms),
                            unroll=rf.scan_unroll(n_chunks))
    return total / jnp.maximum(mask.sum(), 1.0)


def train_loss(params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, dict]:
    """batch: tokens (B,S) int32, labels (B,S) int32, loss_mask (B,S) bool,
    plus frames/vision for audio/vlm archs."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = _embed(params, cfg, tokens)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ctx = {"mode": "train", "positions": pos}
    mem = _memory(params, cfg, batch)
    if mem is not None:
        ctx["memory"] = mem
    h, _, aux = _run_stack(params, cfg, h, ctx)
    loss = _chunked_xent(params, cfg, h, batch["labels"], batch["loss_mask"])
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux, "tokens": batch["loss_mask"].sum()}


# ---------------------------------------------------------------------------
# Serve: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Zeroed decode cache (group-stacked)."""
    shapes = B.group_cache_shapes(cfg, batch, cache_len)

    def mk(leaf):
        shape, dtype = leaf
        return jnp.zeros((cfg.n_groups,) + shape, dtype)

    def is_shape_leaf(x):
        return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)

    cache: dict = {"layers": jax.tree.map(mk, shapes, is_leaf=is_shape_leaf)}
    if cfg.first_layer_dense_ff:
        dense_cfg = cfg.scaled(block_pattern=("attn",), d_ff=cfg.first_layer_dense_ff,
                               n_experts=0)
        cache["layer0"] = jax.tree.map(  # not scanned: no leading groups axis
            lambda leaf: jnp.zeros(*leaf),
            B.group_cache_shapes(dense_cfg, batch, cache_len),
            is_leaf=is_shape_leaf)
    return cache


def prefill(params, cfg: ModelConfig, batch: dict, cache_len: int | None = None):
    """Run the full prompt; returns (last-position logits, cache). The cache
    is allocated at ``cache_len`` (>= prompt length) so decode can append."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = _embed(params, cfg, tokens)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    ctx = {"mode": "prefill", "positions": pos}
    mem = _memory(params, cfg, batch)
    if mem is not None:
        ctx["memory"] = mem
    # Prefill writes caches: run with a zeroed cache pytree; each sublayer
    # emits its cache (prompt K/V written at slots [0, s), recurrent final
    # states, or cross-attention memory K/V).
    cache0 = init_cache(cfg, b, cache_len or s)
    h, new_cache, _ = _run_stack(params, cfg, h, ctx, cache0)
    logits = _logits(params, cfg, h[:, -1:, :])
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, token: jax.Array, cache,
                cache_pos: jax.Array):
    """One decode step. token (B, 1) int32; cache from init_cache/prefill;
    cache_pos: scalar absolute position. Returns (logits, new_cache)."""
    b = token.shape[0]
    h = _embed(params, cfg, token)
    pos = jnp.broadcast_to(cache_pos[None, None], (b, 1)).astype(jnp.int32)
    ctx = {"mode": "decode", "positions": pos, "cache_pos": cache_pos}
    h, new_cache, _ = _run_stack(params, cfg, h, ctx, cache)
    return _logits(params, cfg, h), new_cache
