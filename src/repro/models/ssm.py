"""State-space and recurrent blocks: Mamba (selective SSM), and the xLSTM
pair (mLSTM matrix memory, sLSTM scalar memory).

TPU notes (DESIGN.md hardware adaptation):
* Mamba training uses the chunkwise-parallel form — within a chunk the
  recurrence h_t = a_t ⊙ h_{t-1} + b_t is an associative scan (log-depth,
  no while-loop), chunks are carried by a short lax.scan. Chunk size bounds
  the (B, chunk, d_inner, d_state) working set; d_inner is TP-sharded.
* mLSTM uses the chunkwise linear-attention form: intra-chunk quadratic
  scores (MXU matmuls) + inter-chunk carried (hd × hd) matrix state.
* sLSTM is a true nonlinear recurrence (h_{t-1} feeds the gates); it cannot
  be parallelized over time and lowers to a sequential lax.scan — this is
  inherent to the architecture, not a port artifact.

Decode paths carry O(1)-per-token state, which is why the ssm/hybrid archs
are the ones assigned the long_500k shape.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import runtime_flags as rf
from repro.models.spec import TensorSpec


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------

def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def _chunk_len(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (shapes are static)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def mamba_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    dtr = _dt_rank(cfg)
    return {
        "in_proj": TensorSpec((d, 2 * di), ("embed", "mlp")),
        "conv_w": TensorSpec((cfg.ssm_conv, di), (None, "mlp"), scale=cfg.ssm_conv ** -0.5),
        "conv_b": TensorSpec((di,), ("mlp",), init="zeros"),
        "x_proj": TensorSpec((di, dtr + 2 * ds), ("mlp", None)),
        "dt_proj": TensorSpec((dtr, di), (None, "mlp"), scale=dtr ** -0.5),
        "dt_bias": TensorSpec((di,), ("mlp",), init="zeros"),
        "a_log": TensorSpec((di, ds), ("mlp", None), init="ones"),
        "d_skip": TensorSpec((di,), ("mlp",), init="ones"),
        "out_proj": TensorSpec((di, d), ("mlp", "embed")),
    }


def _mamba_gates(p: dict, cfg: ModelConfig, xz: jax.Array, conv_state=None):
    """Shared front half: split, causal depthwise conv, selective params.
    xz: (B, S, 2*di). Returns (x, z, dt, bsel, csel, new_conv_state)."""
    di = cfg.ssm_expand * cfg.d_model
    ds = cfg.ssm_state
    dtr = _dt_rank(cfg)
    x, z = xz[..., :di], xz[..., di:]

    k = cfg.ssm_conv
    if conv_state is None:  # full-sequence causal depthwise conv
        pad = jnp.zeros(x.shape[:1] + (k - 1,) + x.shape[2:], x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_conv_state = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros_like(pad)
        x = sum(xp[:, i:i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype)
                for i in range(k))
    else:  # single step: conv_state (B, k-1, di)
        window = jnp.concatenate([conv_state, x], axis=1)  # (B, k, di)
        new_conv_state = window[:, 1:, :]
        x = jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(x.dtype))[:, None, :]
    x = jax.nn.silu(x + p["conv_b"].astype(x.dtype))

    sel = jnp.einsum("bsd,dr->bsr", x, p["x_proj"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", sel[..., :dtr], p["dt_proj"].astype(x.dtype))
        + p["dt_bias"].astype(x.dtype))                       # (B,S,di)
    bsel = sel[..., dtr:dtr + ds]                              # (B,S,ds)
    csel = sel[..., dtr + ds:]                                 # (B,S,ds)
    return x, z, dt, bsel, csel, new_conv_state


def mamba(p: dict, cfg: ModelConfig, h_in: jax.Array, *,
          state: jax.Array | None = None, conv_state: jax.Array | None = None):
    """Mamba block. Full-sequence mode (state=None) or decode mode (state
    (B, di, ds), conv_state (B, k-1, di), h_in (B, 1, D)).
    Returns (out, (state, conv_state))."""
    di = cfg.ssm_expand * cfg.d_model
    ds = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", h_in, p["in_proj"].astype(h_in.dtype))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))               # (di, ds)

    if state is None:
        x, z, dt, bsel, csel, conv_out = _mamba_gates(p, cfg, xz)
        b, s, _ = x.shape
        c = _chunk_len(s, cfg.ssm_chunk)
        n_chunks = s // c

        # Chunked scan: the (B, c, di, ds) decay/drive tensors exist only per
        # chunk inside the (rematted) body — never (B, S, di, ds) at once.
        def by_chunk(t):  # (B, S, ...) -> (n_chunks, B, c, ...)
            return t.reshape((b, n_chunks, c) + t.shape[2:]).swapaxes(0, 1)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        def chunk_step(h0, inputs):
            dt_c, x_c, b_c, c_c = inputs                        # (B,c,...)
            dec = jnp.exp(dt_c[..., None] * a)                  # (B,c,di,ds)
            drv = (dt_c * x_c)[..., None] * b_c[:, :, None, :]
            acum, hloc = jax.lax.associative_scan(combine, (dec, drv), axis=1)
            hs = hloc + acum * h0[:, None]                      # (B,c,di,ds)
            y_c = jnp.einsum("bcdn,bcn->bcd", hs, c_c)          # (B,c,di)
            return hs[:, -1], y_c

        chunk_step = jax.checkpoint(chunk_step)
        h0 = jnp.zeros((b, di, ds), jnp.float32)
        new_state, ys = jax.lax.scan(
            chunk_step, h0,
            (by_chunk(dt.astype(jnp.float32)), by_chunk(x.astype(jnp.float32)),
             by_chunk(bsel.astype(jnp.float32)), by_chunk(csel.astype(jnp.float32))),
            unroll=rf.scan_unroll(n_chunks))
        y = ys.swapaxes(0, 1).reshape(b, s, di)
    else:
        x, z, dt, bsel, csel, conv_out = _mamba_gates(p, cfg, xz, conv_state)
        dta = dt[:, 0].astype(jnp.float32)                      # (B,di)
        decay = jnp.exp(dta[..., None] * a)                     # (B,di,ds)
        drive = (dta * x[:, 0].astype(jnp.float32))[..., None] * \
            bsel[:, 0].astype(jnp.float32)[:, None, :]
        new_state = decay * state + drive
        y = jnp.einsum("bdn,bn->bd", new_state, csel[:, 0].astype(jnp.float32))[:, None]

    y = y.astype(h_in.dtype) + x * p["d_skip"].astype(h_in.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(h_in.dtype))
    return out, (new_state, conv_out)


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory, chunkwise-parallel)
# ---------------------------------------------------------------------------

def mlstm_spec(cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    return {
        "wq": TensorSpec((d, h, hd), ("embed", "heads", "qkv")),
        "wk": TensorSpec((d, h, hd), ("embed", "heads", "qkv")),
        "wv": TensorSpec((d, h, hd), ("embed", "heads", "qkv")),
        "wi": TensorSpec((d, h), ("embed", "heads"), scale=d ** -0.5),
        "wf": TensorSpec((d, h), ("embed", "heads"), scale=d ** -0.5),
        "wo_gate": TensorSpec((d, h, hd), ("embed", "heads", "qkv")),
        "out": TensorSpec((h, hd, d), ("heads", "qkv", "embed")),
    }


def mlstm(p: dict, cfg: ModelConfig, h_in: jax.Array, *,
          state: tuple[jax.Array, jax.Array] | None = None):
    """mLSTM. Training: chunkwise parallel. Decode: state=(C (B,H,hd,hd),
    n (B,H,hd)), h_in (B,1,D). Returns (out, (C, n))."""
    b, s, d = h_in.shape
    nh, hd = cfg.n_heads, cfg.resolved_head_dim
    dt = h_in.dtype
    q = jnp.einsum("bsd,dhk->bhsk", h_in, p["wq"].astype(dt)) * (hd ** -0.5)
    k = jnp.einsum("bsd,dhk->bhsk", h_in, p["wk"].astype(dt)) * (hd ** -0.5)
    v = jnp.einsum("bsd,dhk->bhsk", h_in, p["wv"].astype(dt))
    logi = jnp.einsum("bsd,dh->bhs", h_in, p["wi"].astype(dt)).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bhs", h_in, p["wf"].astype(dt)).astype(jnp.float32))

    if state is None:
        c = _chunk_len(s, cfg.ssm_chunk)
        n_chunks = s // c

        def reshape_c(x):  # (B,H,S,...) -> (n_chunks, B,H,c,...)
            return x.reshape(x.shape[:2] + (n_chunks, c) + x.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)

        qc, kc, vc = reshape_c(q), reshape_c(k), reshape_c(v)
        lic, lfc = reshape_c(logi), reshape_c(logf)

        def chunk(carry, xs):
            C0, n0 = carry                                     # (B,H,hd,hd),(B,H,hd)
            qq, kk, vv, li, lf = xs
            fcum = jnp.cumsum(lf, axis=-1)                     # (B,H,c)
            # intra-chunk: scores_ij = exp(fcum_i - fcum_j + i_j) for i >= j
            logD = fcum[..., :, None] - fcum[..., None, :] + li[..., None, :]
            mask = jnp.tril(jnp.ones((c, c), bool))
            logD = jnp.where(mask, logD, -jnp.inf)
            stab = jnp.maximum(jnp.max(logD, axis=-1, keepdims=True), fcum[..., :, None])
            D = jnp.exp(logD - stab)                           # (B,H,c,c)
            # dots stay bf16 with f32 accumulation: halves HBM traffic vs
            # materializing f32 operands (measured; EXPERIMENTS §Perf xlstm)
            f32 = jnp.float32
            scores = jnp.einsum("bhik,bhjk->bhij", qq, kk,
                                preferred_element_type=f32) * D
            y_intra = jnp.einsum("bhij,bhjk->bhik", scores.astype(qq.dtype),
                                 vv, preferred_element_type=f32)
            # inter-chunk contribution
            inter_w = jnp.exp(fcum[..., :, None] - stab)        # (B,H,c,1)
            y_inter = jnp.einsum("bhik,bhkl->bhil", qq,
                                 C0.astype(qq.dtype),
                                 preferred_element_type=f32) * inter_w
            nrm = jnp.einsum("bhik,bhk->bhi", qq, n0.astype(qq.dtype),
                             preferred_element_type=f32)[..., None] * inter_w \
                + jnp.einsum("bhij->bhi", scores)[..., None]
            # scores/nrm carry an exp(-stab) scale; the xLSTM "max(|n q|, 1)"
            # floor is 1 in RAW units = exp(-stab) in stabilized units.
            y = (y_intra + y_inter) / jnp.maximum(jnp.abs(nrm), jnp.exp(-stab))
            # state update to end of chunk
            ftot = fcum[..., -1:]                              # (B,H,1)
            wdec = jnp.exp(ftot - fcum + li)                   # (B,H,c)
            kw = kk * wdec[..., None].astype(kk.dtype)
            C1 = jnp.exp(ftot)[..., None] * C0 + jnp.einsum(
                "bhjk,bhjl->bhkl", kw, vv, preferred_element_type=f32)
            n1 = jnp.exp(ftot) * n0 + jnp.sum(kw.astype(f32), axis=-2)
            return (C1, n1), y

        C0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
        (Cf, nf), ys = jax.lax.scan(chunk, (C0, n0), (qc, kc, vc, lic, lfc),
                                    unroll=rf.scan_unroll(n_chunks))
        y = ys.swapaxes(0, 2).swapaxes(0, 1).reshape(b, nh, s, hd)
        new_state = (Cf, nf)
    else:
        C0, n0 = state
        i1 = jnp.exp(logi[..., 0])                             # (B,H)
        f1 = jnp.exp(logf[..., 0])
        C1 = f1[..., None, None] * C0 + i1[..., None, None] * jnp.einsum(
            "bhk,bhl->bhkl", k[:, :, 0].astype(jnp.float32), v[:, :, 0].astype(jnp.float32))
        n1 = f1[..., None] * n0 + i1[..., None] * k[:, :, 0].astype(jnp.float32)
        num = jnp.einsum("bhk,bhkl->bhl", q[:, :, 0].astype(jnp.float32), C1)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", q[:, :, 0].astype(jnp.float32), n1))
        y = (num / jnp.maximum(den, 1.0)[..., None])[:, :, None, :]
        new_state = (C1, n1)

    o = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bhsk", h_in, p["wo_gate"].astype(dt)))
    y = (y.astype(dt) * o).swapaxes(1, 2)                      # (B,S,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", y, p["out"].astype(dt))
    return out, new_state


# ---------------------------------------------------------------------------
# xLSTM: sLSTM (scalar memory, sequential recurrence)
# ---------------------------------------------------------------------------

def slstm_spec(cfg: ModelConfig) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    return {
        "wz": TensorSpec((d, h, hd), ("embed", "heads", "qkv")),
        "wi": TensorSpec((d, h, hd), ("embed", "heads", "qkv"), scale=d ** -0.5),
        "wf": TensorSpec((d, h, hd), ("embed", "heads", "qkv"), scale=d ** -0.5),
        "wo": TensorSpec((d, h, hd), ("embed", "heads", "qkv")),
        # head-local recurrent mats, FUSED (z|i|f) so the sequential scan
        # does ONE (hd, 3hd) matmul per step instead of three (§Perf xlstm)
        "r": TensorSpec((h, hd, 3 * hd), ("heads", "qkv", None), scale=hd ** -0.5),
        "out": TensorSpec((h, hd, d), ("heads", "qkv", "embed")),
    }


def _slstm_step(r, carry, xs):
    """One sLSTM step. carry = (h, c, n) f32; xs = gate preactivations."""
    from repro.parallel.sharding import constrain_state
    hp, cp, np_ = carry
    pz, pi, pf, po = (t.astype(jnp.float32) for t in xs)
    rec = jnp.einsum("bhk,hkl->bhl", hp.astype(r.dtype), r,
                     preferred_element_type=jnp.float32)
    rz_, ri_, rf_ = jnp.split(rec, 3, axis=-1)
    z = jnp.tanh(pz + rz_)
    i = jnp.exp(jnp.minimum(pi + ri_, 10.0))
    f = jax.nn.sigmoid(pf + rf_)
    o = jax.nn.sigmoid(po)
    c = f * cp + i * z
    n = f * np_ + i
    hh = o * c / jnp.maximum(n, 1.0)
    # pin the carry: GSPMD otherwise shards hd over "model" and pays a
    # partial-sum all-reduce of the recurrence EVERY timestep
    hh, c, n = (constrain_state(t) for t in (hh, c, n))
    return (hh, c, n), hh


@jax.custom_vjp
def _slstm_scan(r, preacts, state):
    """Sequential sLSTM scan with a HAND-WRITTEN backward pass.

    Autodiff of the scan makes GSPMD emit a partial-sum all-reduce of the
    (H, hd, 3hd) weight-gradient at EVERY timestep (measured 1.24 TB/step on
    xlstm-350m x train_4k). The custom VJP replays the recurrence forward
    (remat), runs one reverse scan for the per-step cotangents, and computes
    the weight gradient as a SINGLE stacked einsum after the loop."""
    (hf, cf, nf), ys = jax.lax.scan(lambda c, x: _slstm_step(r, c, x),
                                    state, preacts)
    return (hf, cf, nf), ys


def _slstm_scan_fwd(r, preacts, state):
    out = _slstm_scan(r, preacts, state)
    return out, (r, preacts, state)


def _slstm_scan_bwd(res, cots):
    r, preacts, state = res
    (d_hf, d_cf, d_nf), d_ys = cots

    # re-run forward saving per-step (h_prev, c_prev, n_prev) [remat]
    def fwd_step(carry, xs):
        new_carry, hh = _slstm_step(r, carry, xs)
        return new_carry, carry             # ys = state BEFORE the step
    _, prevs = jax.lax.scan(lambda c, x: fwd_step(c, x), state, preacts)

    def bwd_step(carry, xs):
        d_h, d_c, d_n = carry
        (pz, pi, pf, po), (hp, cp, np_) = xs
        # recompute step-internal values
        rec = jnp.einsum("bhk,hkl->bhl", hp.astype(r.dtype), r,
                         preferred_element_type=jnp.float32)
        rz_, ri_, rf_ = jnp.split(rec, 3, axis=-1)
        az = pz + rz_
        ai = jnp.minimum(pi + ri_, 10.0)
        z = jnp.tanh(az)
        i = jnp.exp(ai)
        f = jax.nn.sigmoid(pf + rf_)
        o = jax.nn.sigmoid(po)
        c = f * cp + i * z
        n = f * np_ + i
        nmax = jnp.maximum(n, 1.0)
        # hh = o * c / nmax
        d_o = d_h * c / nmax
        d_c = d_c + d_h * o / nmax
        d_nmax = -d_h * o * c / (nmax * nmax)
        d_n = d_n + jnp.where(n > 1.0, d_nmax, 0.0)
        # c = f c_p + i z ; n = f n_p + i
        d_f = d_c * cp + d_n * np_
        d_i = d_c * z + d_n
        d_z = d_c * i
        d_cp = d_c * f
        d_np = d_n * f
        # gates
        d_az = d_z * (1.0 - z * z)
        d_ai = jnp.where(pi + ri_ < 10.0, d_i * i, 0.0)
        d_af = d_f * f * (1.0 - f)
        d_po = d_o * o * (1.0 - o)
        d_rec = jnp.concatenate([d_az, d_ai, d_af], axis=-1)   # (B,H,3hd)
        d_hp = jnp.einsum("bhl,hkl->bhk", d_rec.astype(r.dtype), r,
                          preferred_element_type=jnp.float32)
        return (d_hp, d_cp, d_np), (d_az, d_ai, d_af, d_po, d_rec)

    # d_ys[t] adds to the h-cotangent entering step t's backward:
    def bwd_step2(carry, xs):
        d_h, d_c, d_n = carry
        (pre, prev, dy) = xs
        (d_hp, d_cp, d_np), outs = bwd_step((d_h + dy, d_c, d_n), (pre, prev))
        return (d_hp, d_cp, d_np), outs

    (d_h0, d_c0, d_n0), (d_pz, d_pi, d_pf, d_po, d_recs) = jax.lax.scan(
        bwd_step2, (d_hf, d_cf, d_nf), (preacts, prevs, d_ys), reverse=True)

    # weight gradient: ONE einsum over the stacked sequence (no per-step AR)
    h_prevs = prevs[0]                                        # (S,B,H,hd)
    d_r = jnp.einsum("sbhk,sbhl->hkl", h_prevs.astype(jnp.float32),
                     d_recs.astype(jnp.float32)).astype(r.dtype)
    return d_r, (d_pz, d_pi, d_pf, d_po), (d_h0, d_c0, d_n0)


_slstm_scan.defvjp(_slstm_scan_fwd, _slstm_scan_bwd)


def slstm(p: dict, cfg: ModelConfig, h_in: jax.Array, *,
          state: tuple | None = None):
    """sLSTM with head-local recurrence. state = (h, c, n) each (B,H,hd).
    Sequential over time by construction."""
    b, s, d = h_in.shape
    nh, hd = cfg.n_heads, cfg.resolved_head_dim
    dt = h_in.dtype
    pre_z = jnp.einsum("bsd,dhk->sbhk", h_in, p["wz"].astype(dt)).astype(jnp.float32)
    pre_i = jnp.einsum("bsd,dhk->sbhk", h_in, p["wi"].astype(dt)).astype(jnp.float32)
    pre_f = jnp.einsum("bsd,dhk->sbhk", h_in, p["wf"].astype(dt)).astype(jnp.float32)
    from repro.parallel.sharding import constrain_time_major
    pre_o = jnp.einsum("bsd,dhk->sbhk", h_in, p["wo"].astype(dt)).astype(jnp.float32)
    if s > 1:
        pre_z, pre_i, pre_f, pre_o = (constrain_time_major(t) for t in
                                      (pre_z, pre_i, pre_f, pre_o))
    r = p["r"].astype(dt)  # bf16 recurrence matmul, f32 accumulation

    if state is None:
        h0 = jnp.zeros((b, nh, hd), jnp.float32)
        state = (h0, h0, h0 + 1.0)

    (hf, cf, nf), ys = _slstm_scan(r, (pre_z, pre_i, pre_f, pre_o), state)
    y = ys.swapaxes(0, 1).astype(dt)                           # (B,S,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", y, p["out"].astype(dt))
    return out, (hf, cf, nf)
