"""Sharding rules: logical tensor axes -> mesh axes (DESIGN.md §6).

Training layout (MaxText-class): FSDP/ZeRO-3 over the data axes ("pod" and
"data" compose for multi-pod), tensor parallelism over "model", expert
parallelism over "model" for the MoE expert dim. Serving layouts shard KV
caches batch-over-data and sequence-over-model (SP-decode) because kv-head
counts (1, 4, 8, 10) rarely divide a 16-wide model axis.

Divisibility guard: a mesh axis is only applied to a tensor dim it divides
evenly; otherwise the rule degrades (prefix of the axis tuple, then
replicated). MQA (kv=1) and small head counts fall out automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.spec import TensorSpec, is_spec


# logical axis -> mesh axes (tuples compose). None = replicate.
TRAIN_RULES: dict[str | None, Any] = {
    "embed": ("pod", "data"),     # FSDP: parameters sharded over data axes
    "mlp": "model",               # TP: ffn hidden
    "heads": "model",             # TP: attention heads
    "kv": "model",
    "qkv": None,
    "vocab": "model",             # TP: vocab/logits
    "experts": "model",           # EP
    "layers": None,
    None: None,
}

# Serving: weights stay FSDP+TP sharded (gathered on use); activations are
# batch-sharded. Same param rules work for decode.
SERVE_RULES = TRAIN_RULES


def _mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)  # works for Mesh and AbstractMesh


def _fit_axes(dim: int, want, sizes: dict[str, int]):
    """Return the longest prefix of mesh axes whose product divides dim."""
    if want is None:
        return None
    axes = (want,) if isinstance(want, str) else tuple(want)
    out = []
    prod = 1
    for a in axes:
        if a not in sizes:
            continue
        if dim % (prod * sizes[a]) == 0:
            out.append(a)
            prod *= sizes[a]
        else:
            break
    if not out:
        return None
    return tuple(out) if len(out) > 1 else out[0]


def pspec_for(spec: TensorSpec, mesh: Mesh, rules: dict | None = None) -> P:
    rules = rules or TRAIN_RULES
    sizes = _mesh_axis_sizes(mesh)
    entries = []
    used: set[str] = set()
    for dim, ax in zip(spec.shape, spec.axes):
        want = rules.get(ax)
        fit = _fit_axes(dim, want, sizes)
        # a mesh axis may appear at most once per PartitionSpec
        if fit is not None:
            flat = (fit,) if isinstance(fit, str) else fit
            flat = tuple(a for a in flat if a not in used)
            used.update(flat)
            fit = None if not flat else (flat if len(flat) > 1 else flat[0])
        entries.append(fit)
    return P(*entries)


def param_pspecs(spec_tree, mesh: Mesh, rules: dict | None = None):
    return jax.tree_util.tree_map(
        lambda s: pspec_for(s, mesh, rules), spec_tree, is_leaf=is_spec)


def param_shardings(spec_tree, mesh: Mesh, rules: dict | None = None):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, pspec_for(s, mesh, rules)),
        spec_tree, is_leaf=is_spec)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_pspec(mesh: Mesh, batch: int, ndim: int) -> P:
    """Batch dim over the data axes (when divisible), rest replicated."""
    sizes = _mesh_axis_sizes(mesh)
    axes = batch_axes(mesh)
    prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
    first = axes if axes and batch % prod == 0 else None
    if first is not None and len(first) == 1:
        first = first[0]
    return P(first, *([None] * (ndim - 1)))


def cache_pspec(mesh: Mesh, leaf_shape: tuple[int, ...],
                batch_dim: int = 1) -> P:
    """Decode-cache layout: batch over data axes if divisible; the largest
    remaining dim (sequence / d_inner / head_dim) over "model" if divisible.
    Stacked caches are (n_groups, B, ...) => batch_dim=1 by default; the
    non-scanned layer0 cache is (B, ...) => batch_dim=0."""
    sizes = _mesh_axis_sizes(mesh)
    axes = batch_axes(mesh)
    dprod = int(np.prod([sizes[a] for a in axes])) if axes else 1
    entries: list = [None] * len(leaf_shape)
    bd = min(batch_dim, len(leaf_shape) - 1)
    if axes and leaf_shape[bd] % dprod == 0:
        entries[bd] = axes if len(axes) > 1 else axes[0]
    m = sizes.get("model", 1)
    if m > 1 and len(leaf_shape) > bd + 1:
        # largest dim after the batch dim divisible by the model axis
        cands = [(d, i) for i, d in enumerate(leaf_shape[bd + 1:], start=bd + 1)
                 if d % m == 0]
        if cands:
            _, idx = max(cands)
            entries[idx] = "model"
    return P(*entries)


def cache_shardings(cache_tree, mesh: Mesh):
    def leaf_sharding(path, x):
        bd = 0 if "layer0" in jax.tree_util.keystr(path) else 1
        return NamedSharding(mesh, cache_pspec(mesh, tuple(x.shape), bd))

    return jax.tree_util.tree_map_with_path(leaf_sharding, cache_tree)


def decode_score_pspec(mesh: Mesh) -> P:
    """(B, H, 1, S_kv) decode scores: flash-decode — batch over data,
    KV-seq over model, softmax reduced with tiny cross-shard collectives.
    Without this GSPMD gathers the whole seq-sharded KV cache per layer."""
    axes = batch_axes(mesh)
    first = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(first, None, None, "model")


# --- activation sharding constraint (sequence parallelism) ------------------
# The residual-stream scan carry is L × (B, S, D); at 94 layers it only fits
# HBM if sharded over "model" too (Megatron-SP). The launcher/dry-run sets
# the constraint; unit tests (no mesh) leave it unset.

_ACTIVATION_PSPEC: P | None = None


def set_activation_pspec(spec: P | None) -> None:
    global _ACTIVATION_PSPEC
    _ACTIVATION_PSPEC = spec


def constrain_activation(x: jax.Array) -> jax.Array:
    if _ACTIVATION_PSPEC is None:
        return x
    return jax.lax.with_sharding_constraint(x, _ACTIVATION_PSPEC)


def default_activation_pspec(mesh: Mesh, seq_divisible: bool = True) -> P:
    """(B, S, D) residual stream: batch over data axes, seq over model."""
    axes = batch_axes(mesh)
    first = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(first, "model" if seq_divisible else None, None)


# Megatron-SP boundary: the residual stream between blocks is seq-sharded
# (constrain_activation); attention inputs are explicitly gathered back to
# seq-replicated so q/k/v can shard over heads — GSPMD cannot reshard
# seq->heads through the GQA broadcast+reshape on its own.
_ATTN_IN_PSPEC: P | None = None


def set_attn_input_pspec(spec: P | None) -> None:
    global _ATTN_IN_PSPEC
    _ATTN_IN_PSPEC = spec


def constrain_attn_input(x: jax.Array) -> jax.Array:
    if _ATTN_IN_PSPEC is None:
        return x
    return jax.lax.with_sharding_constraint(x, _ATTN_IN_PSPEC)


def default_attn_input_pspec(mesh: Mesh) -> P:
    axes = batch_axes(mesh)
    first = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(first, None, None)


# Block-input pin (always on for train/prefill, all mixer kinds): (B, S, D)
# batch-over-data, feature dim REPLICATED. Without it GSPMD sometimes shards
# the contraction dim of the qkv/in_proj einsums over "model" and pays a
# partial-sum all-reduce of a (B, hd, S, S)-sized tensor per projection
# (measured 1.65 TB/step on xlstm-350m).
_BLOCK_IN_PSPEC: P | None = None


def set_block_input_pspec(spec: P | None) -> None:
    global _BLOCK_IN_PSPEC
    _BLOCK_IN_PSPEC = spec


def constrain_block_input(x: jax.Array) -> jax.Array:
    if _BLOCK_IN_PSPEC is None:
        return x
    return jax.lax.with_sharding_constraint(x, _BLOCK_IN_PSPEC)


def constrain_state(x: jax.Array) -> jax.Array:
    """(B, ...) recurrent-state tensors: batch over data, rest replicated —
    pins the sLSTM scan carry so its feature dim never lands on "model"."""
    if _BLOCK_IN_PSPEC is None:
        return x
    first = _BLOCK_IN_PSPEC[0]
    return jax.lax.with_sharding_constraint(
        x, P(*((first,) + (None,) * (x.ndim - 1))))


def constrain_time_major(x: jax.Array) -> jax.Array:
    """(S, B, ...) tensors (sLSTM gate preactivations): batch over data,
    everything else replicated. Stops GSPMD from sharding the recurrent
    state's feature dim over "model" (which costs a partial-sum all-reduce
    EVERY timestep — measured 1.24 TB/step on xlstm-350m)."""
    if _BLOCK_IN_PSPEC is None:
        return x
    first = _BLOCK_IN_PSPEC[0]
    return jax.lax.with_sharding_constraint(
        x, P(*((None, first) + (None,) * (x.ndim - 2))))


# (B, H, S_q, S_kv) attention scores: batch over data, query-seq over model.
# Query-seq (not heads) because head counts (40, 16, 48...) rarely divide the
# model axis, while S is always a power-of-two multiple of it.
_SCORE_PSPEC: P | None = None
_DECODE_SCORE_PSPEC: P | None = None


def set_score_pspec(spec: P | None) -> None:
    global _SCORE_PSPEC
    _SCORE_PSPEC = spec


def set_decode_score_pspec(spec: P | None) -> None:
    global _DECODE_SCORE_PSPEC
    _DECODE_SCORE_PSPEC = spec


def constrain_scores(x: jax.Array, decode: bool = False) -> jax.Array:
    spec = _DECODE_SCORE_PSPEC if decode else _SCORE_PSPEC
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def default_score_pspec(mesh: Mesh, n_heads: int | None = None) -> P:
    """(B, H, S_q, S_kv): shard heads over "model" when divisible (Megatron
    attention — dk/dv stay local); else shard query-seq (costs a dk/dv
    all-reduce in backward, but never replicates the S x S tensor)."""
    axes = batch_axes(mesh)
    first = axes if len(axes) > 1 else (axes[0] if axes else None)
    m = _mesh_axis_sizes(mesh).get("model", 1)
    if n_heads is not None and n_heads % m == 0:
        return P(first, "model", None, None)
    return P(first, None, "model", None)
