"""Spherical-overdensity (SO) halo masses via BVH range counts.

The production quantity downstream of FOF/DBSCAN halo finding (HACC's SO
stage, Rockstar's M200): around each halo center, find the radius R_Δ where
the mean enclosed density crosses Δ × the reference density, and report

    M_Δ = (particles inside R_Δ) × particle_mass.

Enclosed counts are ε-sphere range counts on the SAME BVH the clustering
uses — ``sphere_counts`` is the query engine's count protocol with a
PER-QUERY radius (``within(centers, radii)``: each halo probes its own
candidate R via the predicate's radius lane). R_Δ is located by
fixed-iteration bisection (jit-able, fixed shapes): enclosed mean density
is monotonically decreasing outside the core, so ``iters`` halvings
bracket R_Δ to ``r_hi / 2^iters``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bvh import Bvh, build_bvh
from repro.core.geometry import scene_bounds
from repro.core.query import query_count, within

__all__ = ["SoMassResult", "sphere_counts", "so_masses",
           "so_masses_from_counts"]

_FOUR_THIRDS_PI = 4.0 / 3.0 * jnp.pi


class SoMassResult(NamedTuple):
    r_delta: jax.Array   # (H,) f32 — SO radius (0 at invalid slots)
    m_delta: jax.Array   # (H,) f32 — count(R_Δ) * particle_mass
    count: jax.Array     # (H,) int32 — particles inside R_Δ
    bracketed: jax.Array  # (H,) bool — density fell below Δρ_ref by r_max;
    #   False means R_Δ >= r_max and r_delta/m_delta are clamped
    #   underestimates (raise r_max), not converged values.


def sphere_counts(bvh, points: jax.Array, centers: jax.Array,
                  radii: jax.Array) -> jax.Array:
    """Range counts with a per-query radius vector (radii: scalar or (q,)).

    One engine call: ``within`` predicates carry the per-halo radii, the
    count protocol does the rest. ``points`` is kept in the signature for
    backward compatibility (the engine tests leaf volumes directly)."""
    return query_count(
        bvh, within(centers.astype(jnp.float32),
                    jnp.asarray(radii, jnp.float32)))


def so_masses_from_counts(count_fn, centers: jax.Array, valid: jax.Array, *,
                          delta, particle_mass, n_particles, box_volume,
                          r_max, iters: int) -> SoMassResult:
    """The bisection driver, decoupled from WHERE counts come from.

    ``count_fn(centers, radii) -> (H,) int`` returns enclosed particle
    counts; the single-device path closes over a local BVH, the sharded
    pipeline closes over the per-shard tree and ``psum``s across shards —
    either way the driver is one fixed-iteration device loop, so it can run
    inside a ``shard_map`` region with zero host round-trips.
    ``n_particles`` is the GLOBAL particle count defining the reference
    density ``n × particle_mass / box_volume``."""
    rho_ref = (jnp.asarray(delta, jnp.float32)
               * n_particles * jnp.asarray(particle_mass, jnp.float32)
               / jnp.asarray(box_volume, jnp.float32))
    m = jnp.asarray(particle_mass, jnp.float32)
    valid_f = valid.astype(jnp.float32)

    def body(_, state):
        r_lo, r_hi = state
        mid = 0.5 * (r_lo + r_hi)
        cnt = count_fn(centers, mid * valid_f)
        dens = cnt.astype(jnp.float32) * m \
            / (_FOUR_THIRDS_PI * jnp.maximum(mid, 1e-12) ** 3)
        above = dens >= rho_ref
        return jnp.where(above, mid, r_lo), jnp.where(above, r_hi, mid)

    r0 = jnp.full((centers.shape[0],), jnp.asarray(r_max, jnp.float32))
    r_lo, r_hi = jax.lax.fori_loop(0, iters, body,
                                   (jnp.zeros_like(r0), r0))
    r_delta = jnp.where(valid, r_lo, 0.0)
    count = count_fn(centers, r_delta * valid_f)
    count = jnp.where(valid, count, 0)
    # Bracket check: did the density actually cross Δρ_ref inside [0, r_max]?
    cnt_edge = count_fn(centers, r0 * valid_f)
    dens_edge = cnt_edge.astype(jnp.float32) * m / (_FOUR_THIRDS_PI * r0 ** 3)
    return SoMassResult(r_delta=r_delta,
                        m_delta=count.astype(jnp.float32) * m,
                        count=count,
                        bracketed=valid & (dens_edge < rho_ref))


@partial(jax.jit, static_argnames=("iters", "use_64bit"))
def so_masses(points: jax.Array, centers: jax.Array, valid: jax.Array, *,
              delta=200.0, particle_mass=1.0, box_volume=1.0,
              r_max=0.25, iters: int = 20, bvh: Bvh | None = None,
              use_64bit: bool = True) -> SoMassResult:
    """M_Δ / R_Δ around ``centers`` (e.g. the catalog's centers or the
    most-bound proxies). ``valid`` masks real halo slots; invalid slots are
    probed at radius 0 and return zeros. ``bvh``: optional prebuilt tree
    over ``points`` (skips the rebuild when chained after other stages).

    The reference density is the mean particle density
    ``n × particle_mass / box_volume`` (matter-density convention — the
    usual Δ=200 "M200m"-style mass for a unit-box mock).
    """
    n = points.shape[0]
    if bvh is None:
        lo_box, hi_box = scene_bounds(points)
        bvh = build_bvh(points, lo_box, hi_box, use_64bit=use_64bit)
    tree = bvh

    def count_fn(c, r):
        return sphere_counts(tree, points, c, r)

    return so_masses_from_counts(count_fn, centers, valid, delta=delta,
                                 particle_mass=particle_mass, n_particles=n,
                                 box_volume=box_volume, r_max=r_max,
                                 iters=iters)
