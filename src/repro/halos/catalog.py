"""Halo catalogs from DBSCAN labels: fixed-capacity, jit-able reductions.

The paper's challenge problem (§2) ends where our DBSCAN ladder stops — raw
int32 labels. HACC's actual in-situ deliverable is a halo CATALOG: per-halo
particle counts, masses, centers of mass, mean velocities, velocity
dispersions and radii, computed on-device every analysis step (these feed
merger trees and downstream science). This module is that missing half.

Pipeline (all fixed shapes, one jit):

1. **Canonicalization** (``canonicalize_labels``): sort particles by cluster
   root label (noise sorts last under a +inf key); run heads mark new halos;
   ``cumsum(head) - 1`` assigns DENSE provisional halo ids ``0..nprov-1`` in
   ascending-root order. Ids beyond ``capacity`` are dropped and flagged.
2. **Segmented reductions**: an 8-wide feature row per sorted particle —
   ``[1, x, y, z, vx, vy, vz, |v|²]`` — is segment-summed by halo id, giving
   count, Σx, Σv, Σ|v|² in one pass. Because ids are sorted AND dense this
   runs on the Pallas one-hot-matmul kernel (``kernels/segment.py``) or the
   pure-JAX scatter oracle, selected by ``backend``.
3. **Derived quantities**: center of mass, mean velocity, 3-D velocity
   dispersion σ = sqrt(E|v|² − |Ev|²); a second segmented MAX pass over
   |x − center|² yields the max radius.
4. **Mass cut + compaction**: halos with fewer than ``min_count`` particles
   (HACC cuts tiny halos; pass your DBSCAN ``min_pts`` for the paper's cut)
   are dropped and survivors compacted to slots ``0..num_halos-1``, still in
   ascending-root order — so ``catalog.root``'s valid prefix is sorted, and
   root→slot lookup is a ``searchsorted`` (``merge.py`` relies on this).

The same feature-row layout is reused by ``merge.py``: a per-shard partial
catalog row ``[count, Σx, Σv, Σ|v|²]`` is just a weighted pseudo-particle,
so the cross-shard merge is this module's reduction applied one level up.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _kref
from repro.kernels import segment as _kseg

NOISE = jnp.int32(-1)


def _sort_last(dtype):
    """The sort-to-the-back sentinel for a label dtype: iinfo max, so int64
    global labels (distributed runs past 2^31 points) keep a sentinel above
    every real root instead of colliding with hard-coded 2^31-1."""
    return jnp.asarray(jnp.iinfo(jnp.dtype(dtype)).max, dtype)


_SORT_LAST = _sort_last(jnp.int32)  # legacy alias for int32-label callers

__all__ = [
    "NOISE",
    "HaloCatalog",
    "canonicalize_labels",
    "feature_sums",
    "derive_catalog",
    "halo_catalog",
]


class HaloCatalog(NamedTuple):
    """Fixed-capacity halo catalog. Valid halos occupy slots
    ``0..num_halos-1`` (ascending DBSCAN root label); the rest are zeroed
    with ``root == -1``."""

    num_halos: jax.Array      # () int32 — halos surviving the mass cut
    overflow: jax.Array       # () bool — provisional halos exceeded capacity
    root: jax.Array           # (H,) label dtype — DBSCAN root label, -1 empty
    count: jax.Array          # (H,) int32 — particles in halo
    mass: jax.Array           # (H,) f32 — count * particle_mass
    center: jax.Array         # (H, d) f32 — center of mass
    vmean: jax.Array          # (H, d) f32 — mean velocity
    vdisp: jax.Array          # (H,) f32 — 3-D velocity dispersion σ
    rmax: jax.Array           # (H,) f32 — max |x - center| over members
    particle_halo: jax.Array  # (n,) int32 — final slot per particle, -1 none


def _use_pallas(backend: str) -> bool:
    if backend not in ("auto", "pallas", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend == "pallas" or (backend == "auto"
                                   and jax.default_backend() == "tpu")


def _seg_sum(data, seg, num_segments, backend):
    if _use_pallas(backend):
        return _kseg.segment_sum_sorted(data, seg, num_segments)
    return _kref.segment_sum_sorted_ref(data, seg, num_segments)


def _seg_max(data, seg, num_segments, backend):
    if _use_pallas(backend):
        return _kseg.segment_max_sorted(data, seg, num_segments)
    return _kref.segment_max_sorted_ref(data, seg, num_segments)


def canonicalize_labels(labels: jax.Array, capacity: int):
    """Labels -> dense provisional halo ids via sort/segment ops.

    Returns ``(perm, pid_sorted, labels_sorted, member_sorted, nprov,
    overflow)``: ``perm`` sorts particles by root label (noise last);
    ``pid_sorted`` is the dense id per sorted particle, clipped into
    ``[0, capacity)`` and constant over the noise tail (its rows are masked
    out by ``member_sorted``, which is False for noise AND for particles of
    halos beyond capacity)."""
    n = labels.shape[0]
    valid = labels >= 0
    sl = _sort_last(labels.dtype)
    perm = jnp.argsort(jnp.where(valid, labels, sl),
                       stable=True).astype(jnp.int32)
    lab_s = labels[perm]  # keeps the label dtype (int64 global ids at scale)
    valid_s = valid[perm]
    idx = jnp.arange(n, dtype=jnp.int32)
    head = valid_s & ((idx == 0) | (lab_s != jnp.roll(lab_s, 1)))
    pid_raw = jnp.cumsum(head.astype(jnp.int32)) - 1
    nprov = pid_raw[-1] + 1 if n else jnp.int32(0)
    overflow = nprov > capacity
    member_s = valid_s & (pid_raw < capacity)
    pid_s = jnp.clip(pid_raw, 0, capacity - 1)
    return perm, pid_s, lab_s, member_s, nprov, overflow


def feature_sums(points, velocities, labels, *, capacity: int,
                 backend: str = "auto"):
    """Per-provisional-halo raw sums ``[count, Σx, Σv, Σ|v|²]`` (H, 2d+2),
    plus the root label per halo and the canonicalization artifacts.

    This is the per-shard "partial catalog" primitive: the sums combine
    linearly across shards (see ``merge.py``)."""
    perm, pid_s, lab_s, member_s, nprov, overflow = \
        canonicalize_labels(labels, capacity)
    pts_s = points[perm].astype(jnp.float32)
    vel_s = velocities[perm].astype(jnp.float32)
    w = member_s.astype(jnp.float32)[:, None]
    feats = jnp.concatenate(
        [w, pts_s * w, vel_s * w,
         jnp.sum(vel_s ** 2, axis=-1, keepdims=True) * w], axis=1)
    sums = _seg_sum(feats, pid_s, capacity, backend)
    sl = _sort_last(lab_s.dtype)
    root = jnp.full((capacity,), sl, lab_s.dtype) \
        .at[pid_s].min(jnp.where(member_s, lab_s, sl))
    root = jnp.where(root == sl, NOISE, root).astype(lab_s.dtype)
    return sums, root, overflow, perm, pid_s, member_s


def derive_catalog(sums, root, min_count, particle_mass, d: int):
    """Raw sums -> derived per-halo quantities + mass cut + compaction.

    Returns ``(num_halos, root, count, mass, center, vmean, vdisp,
    slot_of_prov)`` where ``slot_of_prov[p]`` maps a provisional halo to its
    final slot (-1 if cut). Compaction is stable, so surviving roots stay in
    ascending order."""
    capacity = sums.shape[0]
    cnt_f = sums[:, 0]
    count = jnp.round(cnt_f).astype(jnp.int32)
    safe = jnp.maximum(cnt_f, 1.0)
    center = sums[:, 1:1 + d] / safe[:, None]
    vmean = sums[:, 1 + d:1 + 2 * d] / safe[:, None]
    ev2 = sums[:, 1 + 2 * d] / safe
    vdisp = jnp.sqrt(jnp.maximum(ev2 - jnp.sum(vmean ** 2, axis=-1), 0.0))

    keep = count >= jnp.maximum(jnp.asarray(min_count, jnp.int32), 1)
    order = jnp.argsort(~keep, stable=True).astype(jnp.int32)
    kept = keep[order]
    num_halos = jnp.sum(keep.astype(jnp.int32))
    slot_of_prov = jnp.zeros((capacity,), jnp.int32) \
        .at[order].set(jnp.arange(capacity, dtype=jnp.int32))
    slot_of_prov = jnp.where(keep, slot_of_prov, -1)

    def compact(a, fill):
        out = a[order]
        mask = kept if a.ndim == 1 else kept[:, None]
        return jnp.where(mask, out, jnp.asarray(fill, a.dtype))

    return (num_halos,
            compact(root, NOISE),
            compact(count, 0),
            compact(cnt_f * jnp.asarray(particle_mass, jnp.float32), 0.0),
            compact(center, 0.0),
            compact(vmean, 0.0),
            compact(vdisp, 0.0),
            slot_of_prov)


@partial(jax.jit, static_argnames=("capacity", "backend"))
def halo_catalog(points: jax.Array, velocities: jax.Array, labels: jax.Array,
                 *, capacity: int, min_count=2, particle_mass=1.0,
                 backend: str = "auto") -> HaloCatalog:
    """DBSCAN labels + phase-space coordinates -> halo catalog.

    ``labels``: (n,) int32 cluster roots (any DBSCAN variant's output, or
    the global ids of ``core/distributed.py``), noise = -1.
    ``capacity``: static max halos; more sets ``overflow`` and drops the
    largest-root surplus. ``min_count``: minimum members (pass the DBSCAN
    ``min_pts`` for the paper's mass cut). ``backend``: "pallas" | "jax" |
    "auto" (Pallas on TPU, scatter oracle elsewhere).
    """
    n, d = points.shape
    sums, root_p, overflow, perm, pid_s, member_s = feature_sums(
        points, velocities, labels, capacity=capacity, backend=backend)
    (num_halos, root, count, mass, center, vmean, vdisp,
     slot_of_prov) = derive_catalog(sums, root_p, min_count, particle_mass, d)

    # Second pass: max radius about the (provisional) center of mass.
    cnt_f = sums[:, 0]
    center_p = sums[:, 1:1 + d] / jnp.maximum(cnt_f, 1.0)[:, None]
    r2_s = jnp.sum((points[perm].astype(jnp.float32) - center_p[pid_s]) ** 2,
                   axis=-1)
    r2_s = jnp.where(member_s, r2_s, -_kseg.SEG_NEG_BIG)
    rmax2_p = _seg_max(r2_s[:, None], pid_s, capacity, backend)[:, 0]
    rmax_p = jnp.sqrt(jnp.maximum(rmax2_p, 0.0))
    # Route each surviving provisional halo's rmax to its compacted slot
    # (cut halos collapse onto slot 0 with a harmless 0-valued max update).
    rmax = jnp.zeros((capacity,), jnp.float32) \
        .at[jnp.clip(slot_of_prov, 0, capacity - 1)] \
        .max(jnp.where(slot_of_prov >= 0, rmax_p, 0.0))

    halo_s = jnp.where(member_s, slot_of_prov[pid_s], -1)
    particle_halo = jnp.zeros((n,), jnp.int32).at[perm].set(halo_s)

    return HaloCatalog(num_halos=num_halos, overflow=overflow, root=root,
                       count=count, mass=mass, center=center, vmean=vmean,
                       vdisp=vdisp, rmax=rmax, particle_halo=particle_halo)
