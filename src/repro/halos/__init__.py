"""Halo-analysis subsystem: DBSCAN labels -> production halo catalogs.

The paper's challenge problem (§2, HACC in-situ analysis) doesn't end at
cluster labels: the deliverable every analysis step is a halo CATALOG —
per-halo masses, centers, velocity dispersions — that feeds merger trees
and downstream science (Rangel et al., "Building Halo Merger Trees from the
Q Continuum Simulation"; Tokuue et al., "MPI-Rockstar"). This package is
that production half, built on the repo's search index (``core/bvh.py``),
kernels (``kernels/segment.py``) and distributed path
(``core/distributed.py``).

Module map
----------

``catalog.py``
    Label canonicalization (sort/segment → dense halo ids) and the
    fixed-capacity, jit-able segmented reductions: particle count, center
    of mass, mean velocity, velocity dispersion, max radius; min-count
    halo mass cut. Entry point: ``halo_catalog``.
``centers.py``
    Most-bound-particle proxy centers: softened ε-truncated potentials via
    fused BVH ε-neighborhood traversals, per-halo argmin. Entry point:
    ``most_bound_centers``.
``so_mass.py``
    Spherical-overdensity masses (M_Δ/R_Δ): fixed-iteration bisection on
    the SO radius driven by per-query-radius BVH range counts. Entry
    point: ``so_masses``.
``merge.py``
    Distributed catalog reduction composing with the sharded DBSCAN:
    per-shard partial catalogs (raw per-root sums) merged by global root
    label across shards, plus the centers-dependent max-radius second
    pass. Entry points: ``halo_catalog_sharded`` (shard_map driver), the
    pure ``partial_catalog`` / ``merge_partial_catalogs`` pieces, and
    ``halo_pipeline_sharded`` — the ONE-shard_map-region fusion of the
    whole chain (per-shard BVH build → ε-ghost exchange → distributed
    DBSCAN → catalog merge → SO masses) with zero host round-trips.

Reductions run on the Pallas one-hot-matmul segment kernel
(``kernels/segment.py``) on TPU and on the pure-JAX scatter oracle
elsewhere (``backend=`` argument); both paths agree to float32 sums and are
validated against ``core/ref_numpy.halo_catalog_ref``.
"""
from repro.halos.catalog import HaloCatalog, halo_catalog
from repro.halos.centers import MostBoundResult, most_bound_centers
from repro.halos.merge import (
    HaloPipelineResult,
    PartialCatalog,
    halo_catalog_sharded,
    halo_pipeline_sharded,
    merge_partial_catalogs,
    partial_catalog,
)
from repro.halos.so_mass import SoMassResult, so_masses, so_masses_from_counts

__all__ = [
    "HaloCatalog",
    "halo_catalog",
    "MostBoundResult",
    "most_bound_centers",
    "PartialCatalog",
    "HaloPipelineResult",
    "partial_catalog",
    "merge_partial_catalogs",
    "halo_catalog_sharded",
    "halo_pipeline_sharded",
    "SoMassResult",
    "so_masses",
    "so_masses_from_counts",
]
