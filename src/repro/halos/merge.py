"""Distributed halo-catalog reduction: merge per-shard partials by root.

``core/distributed.py`` ends with GLOBAL labels (cluster root = min global
particle id) sharded across the mesh. Halos straddle slab boundaries, so no
shard can finalize a catalog alone — the HACC pattern is: each rank reduces
its LOCAL particles into per-root partial sums, partial catalogs are merged
by root label across ranks, and centers-dependent quantities take one more
local pass.

The key identity (see ``catalog.py``): a partial-catalog row
``[count, Σx, Σv, Σ|v|²]`` is a weighted pseudo-particle in the exact
feature layout of the single-device reduction — so the cross-shard merge IS
``catalog.feature_sums``'s segmented reduction applied one level up, with
the partial rows as input and their stored counts as weights.

Protocol (``halo_catalog_sharded``, shard_map over the mesh axis):

1. every shard: ``partial_catalog`` over its local particles (one segmented
   reduction keyed on the global root label);
2. ``all_gather`` the fixed-capacity partial tables (S × H rows);
3. every shard runs the same deterministic ``merge_partial_catalogs`` →
   identical full catalogs, replicated;
4. max-radius second pass: each shard scatter-maxes its local particles'
   |x − center|² against the merged centers (root→slot via searchsorted on
   the catalog's ascending-root prefix), combined with ``lax.pmax``.

The pure functions (1)(3)(4) are also usable host-side without a mesh —
``tests/test_halos.py`` drives them shard-by-shard and checks exact
agreement with the single-device catalog.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.halos import catalog as _cat
from repro.halos.catalog import HaloCatalog, NOISE, _sort_last
from repro.kernels.segment import SEG_NEG_BIG

__all__ = [
    "PartialCatalog",
    "HaloPipelineResult",
    "partial_catalog",
    "merge_partial_catalogs",
    "local_rmax2",
    "particle_slots",
    "finalize_rmax",
    "halo_catalog_sharded",
    "halo_pipeline_sharded",
    "halo_pipeline_traced",
]


class PartialCatalog(NamedTuple):
    """Per-shard halo sums keyed by GLOBAL root label (-1 = empty row)."""

    root: jax.Array      # (H,) label dtype (int64 global ids at scale)
    sums: jax.Array      # (H, 2d+2) f32 — [count, Σx, Σv, Σ|v|²]
    overflow: jax.Array  # () bool


@partial(jax.jit, static_argnames=("capacity", "backend"))
def partial_catalog(points: jax.Array, velocities: jax.Array,
                    labels: jax.Array, *, capacity: int,
                    backend: str = "auto") -> PartialCatalog:
    """One shard's raw per-root sums (linear in particles — mergeable)."""
    sums, root, overflow, _, _, _ = _cat.feature_sums(
        points, velocities, labels, capacity=capacity, backend=backend)
    return PartialCatalog(root=root, sums=sums, overflow=overflow)


def merge_partial_catalogs(roots: jax.Array, sums: jax.Array, *,
                           capacity: int, min_count=2, particle_mass=1.0,
                           n_particles: int = 0) -> HaloCatalog:
    """Concatenated partial rows (S·H,) / (S·H, 2d+2) -> merged catalog.

    Rows are pseudo-particles: canonicalize roots, segment-sum the stored
    sums, derive. ``rmax`` needs particle data and comes back zeroed — run
    the ``local_rmax2`` + ``finalize_rmax`` second pass. ``particle_halo``
    is shape (n_particles,) of -1 (per-shard maps come from
    ``particle_slots``)."""
    d = (sums.shape[1] - 2) // 2
    # Empty partial rows (root -1 or zero count) become noise, then the rows
    # canonicalize exactly like particles do.
    roots_eff = jnp.where((roots >= 0) & (sums[:, 0] > 0), roots, -1)
    perm, pid_s, root_s, member_s, _nprov, overflow = \
        _cat.canonicalize_labels(roots_eff, capacity)

    rows = jnp.where(member_s[:, None], sums[perm], 0.0)
    # Merged rows count is small (S·H) — the plain scatter oracle is right.
    merged = jnp.zeros((capacity, sums.shape[1]), jnp.float32) \
        .at[pid_s].add(rows)
    sl = _sort_last(root_s.dtype)
    root_m = jnp.full((capacity,), sl, root_s.dtype) \
        .at[pid_s].min(jnp.where(member_s, root_s, sl))
    root_m = jnp.where(root_m == sl, NOISE, root_m).astype(root_s.dtype)

    (num_halos, root, count, mass, center, vmean, vdisp, _slot) = \
        _cat.derive_catalog(merged, root_m, min_count, particle_mass, d)
    return HaloCatalog(
        num_halos=num_halos, overflow=overflow, root=root, count=count,
        mass=mass, center=center, vmean=vmean, vdisp=vdisp,
        rmax=jnp.zeros((capacity,), jnp.float32),
        particle_halo=jnp.full((max(n_particles, 1),), -1, jnp.int32))


def particle_slots(labels: jax.Array, cat: HaloCatalog) -> jax.Array:
    """Root label per particle -> catalog slot (-1 if noise/cut), via
    searchsorted on the catalog's ascending-root valid prefix."""
    capacity = cat.root.shape[0]
    key = jnp.where(cat.count > 0, cat.root, _sort_last(cat.root.dtype))
    pos = jnp.searchsorted(key, jnp.maximum(labels, 0)).astype(jnp.int32)
    pos_c = jnp.clip(pos, 0, capacity - 1)
    found = (labels >= 0) & (pos < capacity) & (key[pos_c] == labels)
    return jnp.where(found, pos_c, -1)


def local_rmax2(points: jax.Array, labels: jax.Array,
                cat: HaloCatalog) -> jax.Array:
    """One shard's contribution to per-halo max |x − center|² (−BIG where
    the shard holds no members)."""
    capacity = cat.root.shape[0]
    slot = particle_slots(labels, cat)
    r2 = jnp.sum((points.astype(jnp.float32)
                  - cat.center[jnp.clip(slot, 0, capacity - 1)]) ** 2,
                 axis=-1)
    r2 = jnp.where(slot >= 0, r2, -SEG_NEG_BIG)
    return jnp.full((capacity,), -SEG_NEG_BIG, jnp.float32) \
        .at[jnp.clip(slot, 0, capacity - 1)].max(r2)


def finalize_rmax(cat: HaloCatalog, rmax2: jax.Array) -> HaloCatalog:
    """Install the (already cross-shard-combined) max radius²."""
    rmax = jnp.sqrt(jnp.maximum(rmax2, 0.0))
    return cat._replace(rmax=jnp.where(cat.count > 0, rmax, 0.0))


def halo_catalog_sharded(points: jax.Array, velocities: jax.Array,
                         labels: jax.Array, *, mesh: Mesh,
                         axis: str = "data", capacity: int,
                         min_count=2, particle_mass=1.0,
                         backend: str = "auto") -> HaloCatalog:
    """Sharded labels→catalog, composing with ``dbscan_distributed``.

    Inputs are (n_total, …) sharded along ``axis`` (same layout as
    ``dbscan_distributed``'s inputs/outputs; labels are its global root
    ids). Returns the catalog replicated, except ``particle_halo`` which is
    (n_total,) and sharded like the particles.
    """
    n_shards = mesh.shape[axis]
    local_cap = capacity

    def local_fn(pts, vel, lab):
        pts, vel, lab = pts[0], vel[0], lab[0]
        part = partial_catalog(pts, vel, lab, capacity=local_cap,
                               backend=backend)
        roots_all = jax.lax.all_gather(part.root, axis)        # (S, H)
        sums_all = jax.lax.all_gather(part.sums, axis)         # (S, H, F)
        cat = merge_partial_catalogs(
            roots_all.reshape(-1), sums_all.reshape(-1, sums_all.shape[-1]),
            capacity=capacity, min_count=min_count,
            particle_mass=particle_mass)
        rmax2 = jax.lax.pmax(local_rmax2(pts, lab, cat), axis)
        cat = finalize_rmax(cat, rmax2)
        ovf = jax.lax.psum(part.overflow.astype(jnp.int32), axis) > 0
        cat = cat._replace(overflow=cat.overflow | ovf)
        slots = particle_slots(lab, cat)
        return cat._replace(particle_halo=slots[None])

    rep = P()
    out_specs = HaloCatalog(
        num_halos=rep, overflow=rep, root=rep, count=rep, mass=rep,
        center=rep, vmean=rep, vdisp=rep, rmax=rep, particle_halo=P(axis))
    spec = P(axis, None)
    cat = shard_map(
        local_fn, mesh=mesh,
        in_specs=(spec, spec, P(axis, None)),
        out_specs=out_specs, check_rep=False,
    )(points.reshape(n_shards, -1, points.shape[-1]),
      velocities.reshape(n_shards, -1, velocities.shape[-1]),
      labels.reshape(n_shards, -1))
    return cat._replace(particle_halo=cat.particle_halo.reshape(-1))


class HaloPipelineResult(NamedTuple):
    """Everything the one-region pipeline produces in a single device launch."""
    labels: jax.Array         # (n_total,) global DBSCAN labels, sharded
    core_mask: jax.Array      # (n_total,) sharded
    rounds: jax.Array         # () int32 global merge rounds
    halo_overflow: jax.Array  # () bool — ghost buffer overflow anywhere
    catalog: HaloCatalog      # replicated (particle_halo sharded)
    so: "object"              # SoMassResult when so_delta was given, else None


def _pipeline_sharded_gated(fn):
    # jit gated on core count (see core.distributed._jit_ok: XLA:CPU's
    # busy-spin collective rendezvous deadlocks jitted shard_map programs
    # when simulated devices outnumber host cores).
    from repro.core.distributed import _maybe_jit

    return _maybe_jit(
        fn, static_argnames=("min_pts", "capacity", "halo_cap", "axis",
                             "mesh_ref", "min_count", "particle_mass",
                             "max_rounds", "backend", "so_delta", "box_volume",
                             "so_r_max", "so_iters", "index_dtype"))


@_pipeline_sharded_gated
def _pipeline_sharded(points, velocities, eps, min_pts, capacity, halo_cap,
                      axis, mesh_ref, min_count, particle_mass, max_rounds,
                      backend, so_delta, box_volume, so_r_max, so_iters,
                      index_dtype):
    from repro.core.distributed import dbscan_local_shard, shard_context
    from repro.halos.so_mass import so_masses_from_counts, sphere_counts

    mesh = mesh_ref.mesh
    n_shards = mesh.shape[axis]
    n_total = points.shape[0]

    def local_fn(pts, vel):
        pts, vel = pts[0], vel[0]
        # --- build + exchange + cluster (engine traversals, on device) ------
        ctx = shard_context(pts, eps, halo_cap, axis, n_shards,
                            index_dtype=index_dtype)
        labels, core, rounds = dbscan_local_shard(
            pts, eps, min_pts, ctx, axis=axis, max_rounds=max_rounds)
        # --- catalog: partial sums -> all_gather -> replicated merge --------
        part = partial_catalog(pts, vel, labels, capacity=capacity,
                               backend=backend)
        roots_all = jax.lax.all_gather(part.root, axis)
        sums_all = jax.lax.all_gather(part.sums, axis)
        cat = merge_partial_catalogs(
            roots_all.reshape(-1), sums_all.reshape(-1, sums_all.shape[-1]),
            capacity=capacity, min_count=min_count,
            particle_mass=particle_mass)
        rmax2 = jax.lax.pmax(local_rmax2(pts, labels, cat), axis)
        cat = finalize_rmax(cat, rmax2)
        ovf = jax.lax.psum(part.overflow.astype(jnp.int32), axis) > 0
        cat = cat._replace(overflow=cat.overflow | ovf)
        slots = particle_slots(labels, cat)
        cat = cat._replace(particle_halo=slots[None])
        outs = (labels[None], core[None], rounds[None],
                ctx.exchange.overflow[None], cat)
        if so_delta is not None:
            # SO masses against the LOCAL tree, psum'd across shards: the
            # centers are replicated, so every shard probes the same spheres
            # over its own particles and the sum is the global count.
            def count_fn(c, r):
                local = sphere_counts(ctx.bvh_local, pts, c, r)
                return jax.lax.psum(local, axis)

            so = so_masses_from_counts(
                count_fn, cat.center, cat.count > 0, delta=so_delta,
                particle_mass=particle_mass, n_particles=n_total,
                box_volume=box_volume, r_max=so_r_max, iters=so_iters)
            outs = outs + (so,)
        return outs

    rep = P()
    cat_spec = HaloCatalog(
        num_halos=rep, overflow=rep, root=rep, count=rep, mass=rep,
        center=rep, vmean=rep, vdisp=rep, rmax=rep, particle_halo=P(axis))
    out_specs = (P(axis), P(axis), P(axis), P(axis), cat_spec)
    if so_delta is not None:
        from repro.halos.so_mass import SoMassResult
        out_specs = out_specs + (SoMassResult(rep, rep, rep, rep),)
    spec = P(axis, None)
    res = shard_map(
        local_fn, mesh=mesh, in_specs=(spec, spec), out_specs=out_specs,
        check_rep=False,
    )(points.reshape(n_shards, -1, points.shape[-1]),
      velocities.reshape(n_shards, -1, velocities.shape[-1]))
    labels, core, rounds, ovf, cat = res[:5]
    cat = cat._replace(particle_halo=cat.particle_halo.reshape(-1))
    return HaloPipelineResult(
        labels=labels.reshape(-1), core_mask=core.reshape(-1),
        rounds=jnp.max(rounds), halo_overflow=jnp.any(ovf), catalog=cat,
        so=res[5] if so_delta is not None else None)


def halo_pipeline_sharded(points: jax.Array, velocities: jax.Array, eps,
                          min_pts: int, *, mesh: Mesh, axis: str = "data",
                          capacity: int, halo_cap: int = 512,
                          min_count: int = 2, particle_mass: float = 1.0,
                          max_rounds: int = 64, backend: str = "auto",
                          so_delta: float | None = None,
                          box_volume: float = 1.0, so_r_max: float = 0.25,
                          so_iters: int = 20, index_dtype=jnp.int32,
                          tracer=None) -> HaloPipelineResult:
    """The paper's exascale pipeline in ONE ``shard_map`` region: per-shard
    BVH build → ε-ghost exchange → distributed DBSCAN → catalog merge →
    max-radius pass → (optionally, with ``so_delta``) SO masses — all engine
    traversals and collectives, zero host round-trips between stages.

    Inputs are (n_total, d) slab-partitioned like ``dbscan_distributed``'s
    (pre-sorted by x, n_total divisible by the axis size). The catalog is
    replicated; ``labels``/``core_mask``/``catalog.particle_halo`` are
    sharded like the particles.

    ``tracer`` (a ``repro.obs.SpanTracer``) wraps the launch in ONE fenced
    span — fusion means the host cannot see stage boundaries; for a
    per-stage trace use :func:`halo_pipeline_traced` (bit-identical staged
    composition, see ``tests/test_sharded_pipeline.py``)."""
    from repro.core.distributed import _mesh_ref

    def run():
        return _pipeline_sharded(
            points, velocities, eps, min_pts, int(capacity), halo_cap, axis,
            _mesh_ref(mesh), min_count, float(particle_mass), max_rounds,
            backend, so_delta, float(box_volume), float(so_r_max), so_iters,
            jnp.dtype(index_dtype))

    if tracer is None:
        return run()
    with tracer.span("halo_pipeline_sharded", n=int(points.shape[0]),
                     shards=int(mesh.shape[axis]), fused=True) as sp:
        res = sp.fence(run())
    tracer.counter("halo_pipeline", rounds=int(res.rounds),
                   num_halos=int(res.catalog.num_halos),
                   halo_overflow=int(res.halo_overflow))
    return res


def halo_pipeline_traced(points: jax.Array, velocities: jax.Array, eps,
                         min_pts: int, *, mesh: Mesh, axis: str = "data",
                         capacity: int, halo_cap: int = 512,
                         min_count: int = 2, particle_mass: float = 1.0,
                         max_rounds: int = 64, backend: str = "auto",
                         so_delta: float | None = None,
                         box_volume: float = 1.0, so_r_max: float = 0.25,
                         so_iters: int = 20, index_dtype=jnp.int32,
                         tracer=None) -> HaloPipelineResult:
    """The STAGED pipeline — ``dbscan_distributed`` → ``halo_catalog_sharded``
    → ``so_masses`` as separate launches, each in its own fenced span, so a
    Perfetto trace shows where the time goes. Produces the same result as
    the fused :func:`halo_pipeline_sharded` (the equivalence the sharded-
    pipeline tests assert), at the cost of host fences between stages —
    this is the observability build, not the production fast path."""
    from repro.core.distributed import dbscan_distributed
    from repro.halos.so_mass import so_masses
    from repro.obs.trace import traced

    def run():
        dd = dbscan_distributed(points, eps, min_pts, mesh=mesh, axis=axis,
                                halo_cap=halo_cap, max_rounds=max_rounds,
                                index_dtype=index_dtype, tracer=tracer)
        cat = traced(tracer, "halo_catalog_sharded", halo_catalog_sharded,
                     points, velocities, dd.labels, mesh=mesh, axis=axis,
                     capacity=int(capacity), min_count=min_count,
                     particle_mass=particle_mass, backend=backend)
        so = None
        if so_delta is not None:
            so = traced(tracer, "so_masses", so_masses, points, cat.center,
                        cat.count > 0, delta=so_delta,
                        particle_mass=particle_mass, box_volume=box_volume,
                        r_max=so_r_max, iters=so_iters)
        return HaloPipelineResult(
            labels=dd.labels, core_mask=dd.core_mask, rounds=dd.rounds,
            halo_overflow=dd.halo_overflow, catalog=cat, so=so)

    if tracer is None:
        return run()
    with tracer.span("halo_pipeline_traced", n=int(points.shape[0]),
                     shards=int(mesh.shape[axis]), fused=False):
        return run()
