"""Most-bound-particle proxy halo centers via BVH ε-neighborhood potentials.

Center-of-mass centers (``catalog.py``) are biased by tidal debris and
infalling substructure; halo finders (Rockstar, HACC's SO stage) prefer the
MOST BOUND PARTICLE — the minimum of the gravitational potential — as the
halo center. The full O(n²) potential is out of budget in-situ, so we use
the standard short-range proxy: a softened potential truncated at ε,

    φ_i = − Σ_{j : r_ij ≤ ε}  1 / sqrt(r_ij² + soft²),

evaluated with the SAME fused query engine the DBSCAN ladder uses
(``core/query.py``: a ``within`` predicate + accumulating callback,
§4.1.1, which receives the squared pair distance from the predicate
gate) — each particle's potential is one ε-query, no neighbor lists
materialized. The self term 1/soft is a constant shift and cannot change
the per-halo argmin.

The per-halo argmin is two segmented scatter-mins over the catalog's
particle→slot map: min potential, then min particle index attaining it
(deterministic tie-break by original index).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bvh import Bvh, build_bvh
from repro.core.geometry import scene_bounds
from repro.core.query import query, within

_BIG = jnp.float32(1e30)

__all__ = ["MostBoundResult", "halo_potentials", "most_bound_centers"]


class MostBoundResult(NamedTuple):
    index: jax.Array      # (H,) int32 — most-bound particle id, -1 empty slot
    center: jax.Array     # (H, d) f32 — its position (0 at empty slots)
    potential: jax.Array  # (H,) f32 — its φ (0 at empty slots)


def halo_potentials(points: jax.Array, eps, *, softening=None,
                    active: jax.Array | None = None,
                    bvh: Bvh | None = None,
                    use_64bit: bool = True) -> jax.Array:
    """Softened ε-truncated potential per particle (lower = more bound).

    ``active`` masks queries: inactive particles (noise) return 0 — note
    they still walk the tree (the mask gates the output, not the traversal),
    so cost scales with n, not member count. Pass ``bvh`` to reuse a tree
    built over the SAME ``points`` (e.g. across pipeline stages)."""
    eps_f = jnp.asarray(eps, jnp.float32)
    soft2 = jnp.square(eps_f * 1e-2 if softening is None
                       else jnp.asarray(softening, jnp.float32))
    if bvh is None:
        lo, hi = scene_bounds(points)
        bvh = build_bvh(points, lo, hi, use_64bit=use_64bit)
    if active is None:
        active = jnp.ones((points.shape[0],), bool)

    def fn(acc, _qi, _j, r2):
        return acc - jax.lax.rsqrt(r2 + soft2), jnp.bool_(False)

    out = query(bvh, within(points.astype(jnp.float32), eps_f), fn,
                jnp.float32(0.0))
    return jnp.where(active, out, 0.0)


@partial(jax.jit, static_argnames=("capacity", "use_64bit"))
def most_bound_centers(points: jax.Array, particle_halo: jax.Array,
                       eps, *, capacity: int, softening=None,
                       bvh: Bvh | None = None,
                       use_64bit: bool = True) -> MostBoundResult:
    """Per-halo most-bound-particle proxy centers.

    ``particle_halo``: the catalog's (n,) particle→slot map (-1 = no halo).
    Only member particles are queried; empty slots return index -1.
    ``bvh``: optional prebuilt tree over ``points`` (skips the rebuild).
    """
    n = points.shape[0]
    member = particle_halo >= 0
    phi = halo_potentials(points, eps, softening=softening, active=member,
                          bvh=bvh, use_64bit=use_64bit)
    slot = jnp.clip(particle_halo, 0, capacity - 1)
    phi_masked = jnp.where(member, phi, _BIG)
    min_phi = jnp.full((capacity,), _BIG, jnp.float32).at[slot].min(phi_masked)
    attains = member & (phi_masked <= min_phi[slot])
    idx = jnp.full((capacity,), n, jnp.int32).at[slot].min(
        jnp.where(attains, jnp.arange(n, dtype=jnp.int32), n))
    found = idx < n
    idx_c = jnp.clip(idx, 0, n - 1)
    center = jnp.where(found[:, None], points[idx_c].astype(jnp.float32), 0.0)
    return MostBoundResult(
        index=jnp.where(found, idx, -1),
        center=center,
        potential=jnp.where(found, min_phi, 0.0))
