"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES

_REGISTRY: dict[str, str] = {
    "gemma2-9b": "repro.configs.gemma2_9b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "codeqwen1.5-7b": "repro.configs.codeqwen15_7b",
    "granite-20b": "repro.configs.granite_20b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_v2",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "jamba-1.5-large-398b": "repro.configs.jamba_15_large",
    # the paper's own benchmark "config" (DBSCAN problem, not an LM)
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    import importlib
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[arch]).CONFIG


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """Assignment rules: decode shapes need a decoder; long_500k needs
    sub-quadratic attention (ssm/hybrid only)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if cfg.has_decoder:
        out.append(SHAPES["decode_32k"])
    if cfg.supports_long_context:
        out.append(SHAPES["long_500k"])
    return out


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get_config",
           "shapes_for"]
