"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. The vision tower is a
STUB per the assignment: input_specs provides precomputed patch embeddings
(B, 1601, 7680) fed through frontend_proj."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    # 8 repeats of [self, self, self, cross, self] = cross at 3, 8, 13, ...
    block_pattern=("attn", "attn", "attn", "cross", "attn"),
    frontend_tokens=1601,
    frontend_dim=7680,
    activation="silu",
    tie_embeddings=False,
    rope_theta=500000.0,
    supports_long_context=False,
)
