"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000; local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf]. long_500k skipped: global layers are full attention."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,                 # gemma2 uses 256, not d_model/heads
    d_ff=14336,
    vocab=256000,
    block_pattern=("attn_local", "attn"),
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    sandwich_norm=True,
    scale_embed=True,
    activation="geglu",
    tie_embeddings=True,
    rope_theta=10000.0,
    supports_long_context=False,
)
