"""Model/run configuration. One frozen dataclass drives model construction,
sharding, the dry-run, and the benchmarks.

Block patterns: a model is ``n_layers`` layers arranged as ``n_layers //
len(block_pattern)`` repeats of ``block_pattern`` (scanned groups). Entries:

  "attn"        — global self-attention + FFN
  "attn_local"  — sliding-window self-attention + FFN (gemma2 local layers)
  "attn_moe"    — self-attention + MoE FFN
  "cross"       — cross-attention (to encoder / modality frontend) + FFN
  "mamba"       — Mamba selective-SSM block (+ FFN if d_ff > 0)
  "mamba_moe"   — Mamba block + MoE FFN
  "mlstm"       — xLSTM matrix-memory block
  "slstm"       — xLSTM scalar-memory block
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None     # default d_model // n_heads

    block_pattern: tuple[str, ...] = ("attn",)

    # --- attention ---
    rope_theta: float = 10000.0
    sliding_window: int | None = None       # for attn_local layers
    attn_softcap: float | None = None       # gemma2 logit softcapping
    final_softcap: float | None = None
    qk_norm: bool = False                   # qwen3-style q/k RMSNorm
    attn_bias: bool = False                 # qwen1.5-style qkv bias

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_layer_dense_ff: int = 0           # deepseek: dense layer 0 with this d_ff
    capacity_factor: float = 1.25
    moe_group_size: int = 2048              # GShard dispatch group (tokens)

    # --- SSM / xLSTM ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # --- enc-dec / multimodal frontends (stubs provide embeddings) ---
    encoder_layers: int = 0                 # >0: encoder-decoder
    frontend_tokens: int = 0                # patch/frame count of the stub
    frontend_dim: int = 0                   # stub embedding dim

    # --- misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    sandwich_norm: bool = False             # gemma2 pre+post block norms
    scale_embed: bool = False               # gemma: embeddings * sqrt(d)
    activation: str = "silu"                # silu (SwiGLU) | gelu
    dtype: str = "bfloat16"                 # activation/compute dtype
    param_dtype: str = "bfloat16"

    # Per-arch shape policy (assignment rules).
    supports_long_context: bool = False     # run long_500k only if True
    has_decoder: bool = True
    # Measured per-arch layout preference (EXPERIMENTS §Perf): seq-sharded
    # scan carries + explicit block-input gathers + accum=1.
    prefer_sp: bool = False

    def __post_init__(self):
        assert self.n_layers % len(self.block_pattern) == 0, \
            (self.name, self.n_layers, self.block_pattern)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so logits shard over a 16-wide TP axis (MaxText-style
        table padding); only seamless (256206) actually pads. Padded logit
        columns are masked to -inf in loss/decoding."""
        if self.vocab % 16 == 0:
            return self.vocab
        return (self.vocab + 511) // 512 * 512

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        pat_len = len(self.block_pattern)
        return dataclasses.replace(
            self,
            n_layers=2 * pat_len if pat_len <= 4 else pat_len,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 2),
            top_k=min(self.top_k, 2),
            expert_d_ff=32 if self.expert_d_ff else 0,
            first_layer_dense_ff=64 if self.first_layer_dense_ff else 0,
            # no-drop capacity: decode/prefill/full-forward agree exactly
            capacity_factor=float(max(self.n_experts, 1)),
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_tokens=8 if self.frontend_tokens else 0,
            frontend_dim=32 if self.frontend_dim else 0,
            sliding_window=16 if self.sliding_window else None,
            ssm_state=8,
            ssm_chunk=8,
            dtype="float32",
            param_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell: what to lower and at what size."""
    name: str                # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
