"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32 = MHA)
d_ff=13440 vocab=92416; qwen1.5 arch (attention QKV bias)
[hf:Qwen/CodeQwen1.5-7B; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    block_pattern=("attn",),
    attn_bias=True,
    activation="silu",
    tie_embeddings=False,
    rope_theta=1000000.0,
    supports_long_context=False,
)
