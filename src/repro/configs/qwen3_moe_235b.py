"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) expert
d_ff=1536 vocab=151936; 128 routed experts top-8, QK-norm
[hf:Qwen/Qwen3-30B-A3B family; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab=151936,
    block_pattern=("attn_moe",),
    n_experts=128,
    n_shared_experts=0,
    top_k=8,
    expert_d_ff=1536,
    qk_norm=True,
    activation="silu",
    tie_embeddings=False,
    rope_theta=1000000.0,
    supports_long_context=False,
    prefer_sp=True,   # measured: collectives -43% vs accum-16 baseline
)
