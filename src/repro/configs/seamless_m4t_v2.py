"""seamless-m4t-large-v2 [audio] — enc-dec, 24L(+24L enc) d_model=1024 16H
(MHA kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf]. The speech
frontend is a STUB: input_specs provides precomputed frame embeddings
(B, 1024 frames, 1024) consumed by the encoder; the decoder cross-attends
to encoder output."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    block_pattern=("attn", "cross"),   # 12 repeats: self+cross decoder pairs
    encoder_layers=24,
    frontend_tokens=1024,
    frontend_dim=1024,
    activation="silu",
    tie_embeddings=True,
    rope_theta=10000.0,
    supports_long_context=False,
)
