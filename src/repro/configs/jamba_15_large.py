"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536; Mamba:attention 1:7 interleave, MoE 16 experts
top-2 on every other layer [arXiv:2403.19887; hf]. Mamba-majority =>
assigned long_500k (the 9 attention layers use the seq-sharded KV cache)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    # 8-layer Jamba block: attn at index 0 (1:7), MoE on odd layers.
    block_pattern=("attn", "mamba_moe", "mamba", "mamba_moe",
                   "mamba", "mamba_moe", "mamba", "mamba_moe"),
    n_experts=16,
    n_shared_experts=0,
    top_k=2,
    expert_d_ff=24576,
    ssm_state=16,
    ssm_expand=2,
    ssm_chunk=256,
    activation="silu",
    tie_embeddings=False,
    rope_theta=10000.0,
    supports_long_context=True,
    prefer_sp=True,   # measured: collectives -14%, HBM traffic -42% (§Perf)
)
