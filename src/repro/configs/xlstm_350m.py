"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304; alternating
sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]. Recurrent O(1) decode
state => assigned long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,                  # pf=2 expansion: inner dim 2*d_model
    d_ff=0,                        # xLSTM blocks carry their own projections
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    ssm_chunk=256,
    activation="gelu",
    tie_embeddings=True,
    supports_long_context=True,
)
