"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (MHA kv=16) expert
d_ff=1408 vocab=102400; 2 shared + 64 routed top-6 fine-grained experts,
dense FFN (d_ff=10944) on layer 0 [arXiv:2401.06066; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=27,                   # + the separate dense layer 0 (28 total)
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,                        # all scanned layers are MoE
    vocab=102400,
    block_pattern=("attn_moe",),
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    first_layer_dense_ff=10944,
    activation="silu",
    tie_embeddings=False,
    rope_theta=10000.0,
    supports_long_context=False,
)
