"""Device-side traversal statistics (the paper's measurement discipline,
made a first-class output).

Every §4 win in the source paper — early termination, stackless ropes,
pair traversal — was found by MEASURING traversal behaviour, not guessing.
``TraversalStats`` is the unified record of that behaviour for one query
batch: per-query counters accumulated INSIDE the traversal loop carry, so
they live on device, jit-trace cleanly, and compose with ``vmap`` /
``shard_map`` like any other engine output (reduce across shards with
:meth:`TraversalStats.psum`).

The engine (``core/query.py``) threads these through all four backends
behind ``with_stats=`` — the vmapped ``stackless``/``stack`` cores and
the ``pair`` protocol carry them per scalar traversal, and the
``pallas`` wavefront kernel accumulates the same columns as masked
per-lane vectors in its while-loop carry (identical values row-for-row
to the stackless core on the same query order, pinned by
``tests/test_wavefront.py``). The stats-OFF path stages the exact
pre-obs jaxpr (machine-checked by the ``stats_path_identity`` audit in
``repro.staticcheck.registry``), so observability is zero-cost when
disabled.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["TraversalStats"]


class TraversalStats(NamedTuple):
    """Per-query traversal counters (all fields shaped ``(q,)``).

    ``nodes_visited``
        traversal-loop iterations (internal nodes walked + leaves reached).
    ``aabb_tests``
        internal-node bounding-volume tests (the descend/skip decisions).
    ``leaf_tests``
        leaf bounding-volume tests — for point leaves this is the exact
        predicate test, so ``leaf_tests >= callback_hits`` always.
    ``callback_hits``
        fused-callback invocations (predicate-satisfying leaves). Zero for
        the generic :func:`repro.core.query.traverse` driver, which has no
        hit notion of its own — the engine protocols fill it in.
    ``early_exits``
        whether this query terminated through the callback's ``done`` flag
        (§4.1.2 ``CallbackTreeTraversalControl``) rather than exhausting
        the tree.
    ``max_depth``
        deepest tree level reached (rope and pallas backends: node depth
        of the deepest visited node; stack backend: high-water stack
        pointer).
    """

    nodes_visited: jax.Array  # (q,) int32
    aabb_tests: jax.Array     # (q,) int32
    leaf_tests: jax.Array     # (q,) int32
    callback_hits: jax.Array  # (q,) int32
    early_exits: jax.Array    # (q,) bool
    max_depth: jax.Array      # (q,) int32

    def totals(self) -> dict[str, jax.Array]:
        """Batch-level scalars (still on device): sums of the counters,
        count of early exits, max of the depth high-water marks."""
        return {
            "nodes_visited": jnp.sum(self.nodes_visited),
            "aabb_tests": jnp.sum(self.aabb_tests),
            "leaf_tests": jnp.sum(self.leaf_tests),
            "callback_hits": jnp.sum(self.callback_hits),
            "early_exits": jnp.sum(self.early_exits.astype(jnp.int32)),
            "max_depth": jnp.max(self.max_depth, initial=0),
        }

    def psum(self, axis: str) -> "TraversalStats":
        """Cross-shard reduction (call inside a ``shard_map`` region):
        counters sum, the depth high-water mark maxes, ``early_exits``
        stays the per-query local column (it is per-query, not global)."""
        return TraversalStats(
            nodes_visited=jax.lax.psum(self.nodes_visited, axis),
            aabb_tests=jax.lax.psum(self.aabb_tests, axis),
            leaf_tests=jax.lax.psum(self.leaf_tests, axis),
            callback_hits=jax.lax.psum(self.callback_hits, axis),
            early_exits=self.early_exits,
            max_depth=jax.lax.pmax(self.max_depth, axis),
        )
