"""Metrics registry: one sink for the observability crumbs the engine
already produces.

The repo grew per-protocol observability organically — ``DeviceCsr`` /
``BufferedCsr`` overflow flags and retry ``attempts``, ``GridAutoInfo``
capacity retries, ``count_compile_signatures`` recompile counts, the halo
exchange's fixed payload buffers. This module unifies them: one
:class:`MetricsRegistry` that any pipeline can ``record`` into (scalars
or device arrays, including shard_map-sharded outputs — conversion to
host floats happens lazily at :meth:`summary` time, so recording costs no
sync), plus :meth:`observe` which knows the repo's observability-bearing
result types and explodes them into named series.
"""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Any, Iterable

import numpy as np

__all__ = ["MetricsRegistry"]


class MetricsRegistry:
    """Append-only metric sink with lazy host aggregation.

    ``record(name, value)`` accepts python numbers, numpy arrays and jax
    arrays (sharded arrays included — ``np.asarray`` gathers at summary
    time, not record time). ``summary()`` aggregates each series over the
    FLATTENED elements of everything recorded under that name: per-shard
    columns recorded from a shard_map driver therefore aggregate to the
    global count/sum/max without any explicit collective.
    """

    def __init__(self):
        self._series: dict[str, list[Any]] = defaultdict(list)

    # --- recording ----------------------------------------------------------

    def record(self, name: str, value) -> None:
        self._series[name].append(value)

    def record_recompiles(self, name: str, sweep: Iterable[tuple]) -> None:
        """Record the number of distinct compiled shapes a workload sweep
        would cost (the serving tier's bucketing premise)."""
        from repro.staticcheck.jaxpr_audit import count_compile_signatures

        self.record(f"{name}/compile_signatures",
                    count_compile_signatures(sweep))

    def observe(self, name: str, obj) -> None:
        """Explode a known observability-bearing result into named series.

        Understands ``DeviceCsr`` / ``BufferedCsr`` / ``ShardedCsr`` (hit
        totals, overflow flags, retry attempts), ``GridAutoInfo`` (capacity
        retries), ``HaloExchange`` (ghost payload volume and overflow) and
        ``TraversalStats`` (the device-side counter totals). Anything else
        falls back to ``record(name, obj)``.
        """
        from repro.core.distributed import HaloExchange, ShardedCsr
        from repro.core.fdbscan_grid import GridAutoInfo
        from repro.core.query import BufferedCsr, DeviceCsr
        from repro.obs.stats import TraversalStats

        if isinstance(obj, DeviceCsr):
            self.record(f"{name}/total", obj.total)
            self.record(f"{name}/overflowed", obj.overflowed)
        elif isinstance(obj, BufferedCsr):
            self.record(f"{name}/total", obj.offsets[-1])
            self.record(f"{name}/attempts", obj.attempts)
            self.record(f"{name}/overflowed", obj.overflowed)
        elif isinstance(obj, ShardedCsr):
            self.record(f"{name}/total", obj.total)       # per-shard column
            self.record(f"{name}/overflowed", obj.overflowed)
        elif isinstance(obj, GridAutoInfo):
            self.record(f"{name}/attempts", obj.attempts)
            self.record(f"{name}/capacity", obj.capacity)
            self.record(f"{name}/overflowed", obj.overflowed)
        elif isinstance(obj, HaloExchange):
            ghosts = obj.halo_valid.astype(np.int32).sum() \
                if isinstance(obj.halo_valid, np.ndarray) else \
                obj.halo_valid.sum()
            self.record(f"{name}/ghost_rows", ghosts)
            self.record(f"{name}/payload_bytes",
                        obj.halo_pts.size * obj.halo_pts.dtype.itemsize
                        + obj.halo_gid.size * obj.halo_gid.dtype.itemsize)
            self.record(f"{name}/overflowed", obj.overflow)
        elif isinstance(obj, TraversalStats):
            for key, val in obj.totals().items():
                self.record(f"{name}/{key}", val)
        else:
            self.record(name, obj)

    # --- aggregation --------------------------------------------------------

    def summary(self) -> dict[str, dict[str, float]]:
        """name -> {records, count, sum, min, max, last} over the flattened
        elements of every value recorded under the name. This is where
        device (possibly sharded) arrays are fetched to host."""
        out: dict[str, dict[str, float]] = {}
        for name, values in self._series.items():
            flat = np.concatenate(
                [np.ravel(np.asarray(v)).astype(np.float64) for v in values])
            out[name] = {
                "records": len(values),
                "count": int(flat.size),
                "sum": float(flat.sum()),
                "min": float(flat.min()),
                "max": float(flat.max()),
                "last": float(flat[-1]),
            }
        return out

    def to_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.summary(), f, indent=2, sort_keys=True)
        return path
