"""Observability layer: device-side traversal stats, host-side span
tracing with Chrome-trace export, and a unifying metrics registry.

See ``obs/stats.py`` (TraversalStats), ``obs/trace.py`` (SpanTracer /
traced), ``obs/metrics.py`` (MetricsRegistry). All three are strictly
opt-in: the engine's stats-off path stages the identical jaxpr it did
before this package existed (machine-checked by
``repro.staticcheck``'s ``stats_path_identity`` audit).
"""
from repro.obs.metrics import MetricsRegistry
from repro.obs.stats import TraversalStats
from repro.obs.trace import (Span, SpanTracer, load_chrome_trace, span_tree,
                             traced)

__all__ = [
    "TraversalStats",
    "Span",
    "SpanTracer",
    "traced",
    "load_chrome_trace",
    "span_tree",
    "MetricsRegistry",
]
