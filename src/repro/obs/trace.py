"""Host-side span tracer with Chrome-trace-event export.

JAX dispatch is asynchronous: wall-clock timestamps around a call measure
dispatch, not compute. Each :class:`Span` therefore carries an optional
FENCE — a pytree of device values that ``jax.block_until_ready`` drains
before the span closes — so a span's duration covers the device work it
launched. Spans nest through a plain stack; the export is Chrome trace
event JSON (``{"traceEvents": [...]}``, "X" complete events), loadable
directly in Perfetto (ui.perfetto.dev) or ``chrome://tracing``.

Usage::

    tracer = SpanTracer()
    with tracer.span("dbscan", n=4096) as sp:
        res = fdbscan(pts, eps, 2)
        sp.fence(res)          # block_until_ready before the span closes
    tracer.export("trace.json")

``traced(tracer, name, fn, *args)`` is the one-liner used by the pipeline
wiring (``halos/merge``, ``core/distributed``, ``analysis/insitu``): when
``tracer`` is None it calls ``fn`` directly — zero overhead, no fencing —
so observability stays strictly opt-in.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

import jax

__all__ = ["Span", "SpanTracer", "traced", "load_chrome_trace", "span_tree"]


class Span:
    """One open span; created by :meth:`SpanTracer.span`."""

    def __init__(self, tracer: "SpanTracer", name: str, depth: int,
                 args: dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.depth = depth
        self.args = args
        self.t0 = 0.0
        self._fences: list[Any] = []

    def fence(self, value):
        """Register device values the span must drain before closing.
        Returns ``value`` so the call can wrap an expression in place."""
        self._fences.append(value)
        return value

    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            for v in self._fences:
                jax.block_until_ready(v)
        self.tracer._close(self, time.perf_counter())


class SpanTracer:
    """Nested spans -> Chrome trace events. Single-threaded by design (one
    ``tid``); nesting is encoded by timestamp containment, which is how
    Perfetto stacks "X" events on a track."""

    def __init__(self, process_name: str = "repro"):
        self.process_name = process_name
        self._epoch = time.perf_counter()
        self._stack: list[Span] = []
        self.events: list[dict] = []

    # --- recording ----------------------------------------------------------

    def span(self, name: str, **args) -> Span:
        sp = Span(self, name, depth=len(self._stack), args=args)
        self._stack.append(sp)
        return sp

    def _close(self, sp: Span, t1: float) -> None:
        # close any dangling children first (exception unwind safety)
        while self._stack and self._stack[-1] is not sp:
            self._stack.pop()
        if self._stack:
            self._stack.pop()
        self.events.append({
            "name": sp.name,
            "ph": "X",
            "ts": (sp.t0 - self._epoch) * 1e6,   # Chrome traces are in us
            "dur": (t1 - sp.t0) * 1e6,
            "pid": os.getpid(),
            "tid": 0,
            "cat": "repro",
            "args": {**sp.args, "depth": sp.depth},
        })

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker event."""
        self.events.append({
            "name": name, "ph": "i", "s": "t",
            "ts": (time.perf_counter() - self._epoch) * 1e6,
            "pid": os.getpid(), "tid": 0, "cat": "repro", "args": args,
        })

    def counter(self, name: str, **series) -> None:
        """A counter track sample (Perfetto renders these as line plots)."""
        self.events.append({
            "name": name, "ph": "C",
            "ts": (time.perf_counter() - self._epoch) * 1e6,
            "pid": os.getpid(), "tid": 0, "cat": "repro",
            "args": {k: float(v) for k, v in series.items()},
        })

    # --- export -------------------------------------------------------------

    def to_chrome(self) -> dict:
        meta = {
            "name": "process_name", "ph": "M", "pid": os.getpid(), "tid": 0,
            "args": {"name": self.process_name},
        }
        return {"traceEvents": [meta] + self.events,
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome-trace JSON; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
        return path


def traced(tracer: SpanTracer | None, name: str, fn: Callable, *args,
           span_args: dict | None = None, **kwargs):
    """Run ``fn(*args, **kwargs)`` inside a fenced span — or, with
    ``tracer=None``, call it directly (the zero-overhead default)."""
    if tracer is None:
        return fn(*args, **kwargs)
    with tracer.span(name, **(span_args or {})) as sp:
        return sp.fence(fn(*args, **kwargs))


# --- round-trip helpers (tests, tooling) ------------------------------------

def load_chrome_trace(path: str) -> list[dict]:
    """Load a Chrome-trace JSON and return its complete ("X") span events,
    sorted by start time."""
    tree = json.loads(open(path).read())
    evs = [e for e in tree["traceEvents"] if e.get("ph") == "X"]
    return sorted(evs, key=lambda e: e["ts"])


def span_tree(events: list[dict]) -> dict[str, list[str]]:
    """Parent -> children mapping recovered purely from timestamp
    containment (the same rule Perfetto uses to stack the track)."""
    out: dict[str, list[str]] = {e["name"]: [] for e in events}
    for i, child in enumerate(events):
        best = None
        for parent in events:
            if parent is child:
                continue
            if (parent["ts"] <= child["ts"]
                    and parent["ts"] + parent["dur"]
                    >= child["ts"] + child["dur"]):
                if best is None or parent["dur"] < best["dur"]:
                    best = parent
        if best is not None:
            out[best["name"]].append(child["name"])
    return out
