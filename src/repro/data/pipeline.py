"""Deterministic synthetic data pipeline.

Production posture without a corpus dependency: an infinite, seekable token
stream — ``batch_at(step)`` is a pure function of (seed, step), so restart/
elastic-reshape resume is exact (the checkpoint stores only the step), and
every data-parallel host can materialize exactly its shard (host-sharded
loading: each host computes only its slice of the global batch).

The generator mixes a Zipf unigram skeleton with deterministic n-gram
structure so losses are non-trivial (a model can actually learn it).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0
    frontend_dim: int = 0


class SyntheticTokens:
    """Seekable deterministic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf-ish unigram distribution, fixed by seed.
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self._probs = probs / probs.sum()
        # deterministic bigram shift pattern for learnable structure
        self._shift = rng.integers(1, 97)

    def _host_slice(self, host_index: int, host_count: int) -> tuple[int, int]:
        per = self.cfg.global_batch // host_count
        return host_index * per, per

    def batch_at(self, step: int, host_index: int = 0, host_count: int = 1) -> dict:
        """Global batch for a step (or this host's rows)."""
        cfg = self.cfg
        start, rows = self._host_slice(host_index, host_count)
        rng = np.random.default_rng((cfg.seed, step))
        # generate the FULL batch deterministically, slice this host's rows —
        # rows are independent streams so we draw per-row for seek-ability.
        toks = np.empty((rows, cfg.seq_len + 1), np.int32)
        for r in range(rows):
            rrng = np.random.default_rng((cfg.seed, step, start + r))
            base = rrng.choice(cfg.vocab, size=cfg.seq_len + 1, p=self._probs)
            # half the positions follow the deterministic bigram rule
            mask = rrng.random(cfg.seq_len) < 0.5
            nxt = (base[:-1] + self._shift) % cfg.vocab
            base[1:] = np.where(mask, nxt, base[1:])
            toks[r] = base
        batch = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
            "loss_mask": jnp.ones((rows, cfg.seq_len), bool),
        }
        if cfg.frontend_dim:
            frng = np.random.default_rng((cfg.seed, step, 77))
            emb = frng.standard_normal(
                (rows, cfg.frontend_tokens, cfg.frontend_dim), np.float32)
            batch["frames"] = jnp.asarray(emb)
            batch["vision"] = batch["frames"]
        return batch


def make_clustered_points(rng: np.random.Generator, n: int, d: int = 3,
                          n_halos: int = 32, noise_frac: float = 0.2,
                          halo_scale: float = 0.05) -> np.ndarray:
    """The paper's benchmark data analogue (DESIGN.md §1): NFW-like halo
    profiles + uniform background in [0,1)^d. Reproduces the Table-1 Morton
    collision phenomenon at scale."""
    n_noise = int(n * noise_frac)
    n_clustered = n - n_noise
    centers = rng.uniform(0.05, 0.95, (n_halos, d))
    # halo masses ~ power law
    w = rng.pareto(1.5, n_halos) + 1
    sizes = rng.multinomial(n_clustered, w / w.sum())
    parts = [rng.uniform(0.0, 1.0, (n_noise, d)).astype(np.float32)]
    for c, s in zip(centers, sizes):
        if s == 0:
            continue
        u = rng.uniform(0, 1, (s, 1)) ** 2.5          # concentrated core
        direction = rng.standard_normal((s, d))
        direction /= np.maximum(np.linalg.norm(direction, axis=1, keepdims=True), 1e-9)
        r = halo_scale * u * (0.3 + rng.uniform(0, 1, (n_halos,))[0])
        # physical floor: N-body particles never coincide; keeps the core
        # denser than 32-bit Morton bins (2^-10) but resolvable at 64-bit
        # (2^-21) — the Table-1 phenomenon without unphysical f32 collisions.
        r = np.maximum(r, 5e-5)
        parts.append((c + r * direction).astype(np.float32))
    pts = np.concatenate(parts)
    return np.clip(pts, 0.0, 1.0 - 1e-6).astype(np.float32)


def hacc_benchmark_epsilon(volume: float, n_particles: int, b: float = 0.168) -> float:
    """The paper's ε convention: ε = b (V/n)^{1/3} (footnote 1)."""
    return b * (volume / n_particles) ** (1.0 / 3.0)
