"""Layer 1: jaxpr invariant audits — trace a callable and enforce the
repo's device-discipline rules on every sub-jaxpr.

This generalizes the ad-hoc walker that used to live inline in
``tests/test_device_csr.py``: given a callable + example args, walk ALL
sub-jaxprs (while_loop/scan/cond bodies, pallas_call kernels, nested
pjit regions) and apply pluggable rules:

* ``no_dense_intermediate(max_elems)`` — no intermediate array at or
  above a size budget. This is how O(n²) staging regressions (the dense
  ``(q, max_count)`` fill buffer the scan-then-scatter CSR replaced, the
  dense neighbor matrices the sharded DBSCAN replaced) are caught at
  trace time, before they cost memory at run time.
* ``no_host_transfer()`` — no host-interaction primitives
  (``callback``-family, infeed/outfeed, ``device_put``) anywhere in a
  device pipeline. The trace-time complement of the runtime
  ``transfer_guard`` checks (see :func:`assert_no_host_transfers`).
* ``bounded_recompiles(cap)`` — drive a workload sweep through a
  shape-signature counter and assert the number of DISTINCT compiled
  shapes stays under ``cap`` (the serving tier's fixed-bucket premise:
  bucketed batching must collapse arbitrary request sizes onto a few
  compiled programs).

Rules are callables ``rule(closed_jaxpr, name) -> list[Finding]`` so new
invariants slot in without touching the walker.
"""
from __future__ import annotations

from typing import Callable, Iterable, Iterator

import numpy as np
import jax

from repro.staticcheck.findings import Finding

__all__ = [
    "iter_subjaxprs",
    "iter_eqns",
    "max_intermediate_elems",
    "no_dense_intermediate",
    "no_host_transfer",
    "audit_jaxpr",
    "jaxpr_op_signature",
    "count_compile_signatures",
    "bounded_recompiles",
    "assert_no_host_transfers",
]

# Primitive names that imply host interaction inside a traced program.
# Matched exactly, plus any primitive whose name contains "callback"
# (pure_callback / io_callback / debug_callback across JAX versions).
_HOST_PRIMS = frozenset({"device_put", "infeed", "outfeed", "host_call"})


def iter_subjaxprs(jaxpr) -> Iterator:
    """Yield ``jaxpr`` and every sub-jaxpr reachable through eqn params
    (while/scan/cond branches, pjit regions, pallas kernels, ...)."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            items = val if isinstance(val, (tuple, list)) else [val]
            for it in items:
                inner = getattr(it, "jaxpr", it)
                if hasattr(inner, "eqns"):
                    yield from iter_subjaxprs(inner)


def iter_eqns(jaxpr) -> Iterator:
    for sub in iter_subjaxprs(jaxpr):
        yield from sub.eqns


def _out_elems(eqn) -> int:
    """Largest output array of one eqn, in elements (0 if shapeless)."""
    biggest = 0
    for v in eqn.outvars:
        aval = getattr(v, "aval", None)
        shape = getattr(aval, "shape", None)
        if shape:
            biggest = max(biggest, int(np.prod(shape)))
    return biggest


def _closed(fn_or_jaxpr, args):
    if hasattr(fn_or_jaxpr, "eqns") or hasattr(fn_or_jaxpr, "jaxpr"):
        return fn_or_jaxpr
    return jax.make_jaxpr(fn_or_jaxpr)(*args)


def max_intermediate_elems(fn, args=()) -> int:
    """Largest intermediate array (elements) over all sub-jaxprs — the
    quantity ``no_dense_intermediate`` budgets. Accepts a callable +
    example args or an already-made (closed) jaxpr."""
    closed = _closed(fn, args)
    jaxpr = getattr(closed, "jaxpr", closed)
    return max((_out_elems(eqn) for eqn in iter_eqns(jaxpr)), default=0)


def no_dense_intermediate(max_elems: int) -> Callable:
    """Rule: every intermediate must stay strictly under ``max_elems``.

    Pick the budget as the size of the dense object the pipeline is NOT
    allowed to stage — e.g. ``q * max_count`` for CSR fills, ``n * n``
    for neighbor pipelines."""
    budget = int(max_elems)

    def rule(closed_jaxpr, name: str) -> list[Finding]:
        jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
        worst_eqn, worst = None, 0
        for eqn in iter_eqns(jaxpr):
            elems = _out_elems(eqn)
            if elems > worst:
                worst_eqn, worst = eqn, elems
        if worst >= budget:
            return [Finding(
                rule="no-dense-intermediate", path=f"<jaxpr:{name}>", line=0,
                message=(f"intermediate of {worst} elems >= budget {budget} "
                         f"(primitive {worst_eqn.primitive.name!r}): the "
                         f"pipeline is staging a dense buffer"))]
        return []

    return rule


def no_host_transfer() -> Callable:
    """Rule: no callback/infeed/outfeed/device_put-class primitive may
    appear anywhere in the traced program."""

    def rule(closed_jaxpr, name: str) -> list[Finding]:
        jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
        findings = []
        seen = set()
        for eqn in iter_eqns(jaxpr):
            pname = eqn.primitive.name
            if (pname in _HOST_PRIMS or "callback" in pname) \
                    and pname not in seen:
                seen.add(pname)
                findings.append(Finding(
                    rule="no-host-transfer", path=f"<jaxpr:{name}>", line=0,
                    message=(f"host-interaction primitive {pname!r} inside a "
                             f"device pipeline")))
        return findings

    return rule


def audit_jaxpr(fn, args, rules: Iterable[Callable], *,
                name: str | None = None) -> list[Finding]:
    """Trace ``fn(*args)`` and apply each rule to the resulting jaxpr.
    Returns the concatenated findings ([] == the invariants hold)."""
    name = name or getattr(fn, "__name__", "fn")
    closed = _closed(fn, args)
    findings: list[Finding] = []
    for rule in rules:
        findings.extend(rule(closed, name))
    return findings


def jaxpr_op_signature(fn, args=()) -> tuple:
    """Stable op-level signature of a traced program: the sequence of
    ``(primitive name, output avals)`` over the jaxpr and every sub-jaxpr
    (while/scan bodies included), in trace order. Two callables with equal
    signatures stage the same ops on the same shapes/dtypes — the equality
    the ``stats_path_identity`` audit uses to prove the engine's
    ``with_stats=False`` path is op-for-op the pre-obs program. Accepts a
    callable + example args or an already-closed jaxpr."""
    closed = _closed(fn, args)
    jaxpr = getattr(closed, "jaxpr", closed)
    return tuple(
        (eqn.primitive.name, tuple(str(v.aval) for v in eqn.outvars))
        for eqn in iter_eqns(jaxpr))


# --- recompile budget (trace a workload sweep) ------------------------------

def _signature(args) -> tuple:
    leaves = jax.tree.leaves(args)
    return tuple((tuple(np.shape(x)), str(getattr(x, "dtype", type(x).__name__)))
                 for x in leaves)


def count_compile_signatures(sweep: Iterable[tuple]) -> int:
    """Number of DISTINCT (shape, dtype) signatures across a sweep of
    example-arg tuples — each distinct signature is one jit cache entry."""
    return len({_signature(args) for args in sweep})


def bounded_recompiles(fn, sweep: Iterable[tuple], cap: int, *,
                       name: str | None = None,
                       check_trace: bool = True) -> list[Finding]:
    """Rule: running ``fn`` over every args-tuple in ``sweep`` must compile
    at most ``cap`` distinct programs (the fixed-bucket serving premise).

    With ``check_trace`` each distinct signature is also traced once, so a
    sweep that would fail to compile is caught here too."""
    name = name or getattr(fn, "__name__", "fn")
    sweep = list(sweep)
    seen: dict[tuple, tuple] = {}
    for args in sweep:
        seen.setdefault(_signature(args), args)
    if check_trace:
        for args in seen.values():
            jax.make_jaxpr(fn)(*args)
    if len(seen) > cap:
        return [Finding(
            rule="bounded-recompiles", path=f"<jaxpr:{name}>", line=0,
            message=(f"{len(seen)} distinct compiled shapes over a "
                     f"{len(sweep)}-point sweep exceeds the cap of {cap}: "
                     f"bucket the workload to fixed shapes"))]
    return []


# --- runtime complement: the transfer-guard assertion -----------------------

def assert_no_host_transfers(fn, *args, guard: str = "all", warmup: bool = True):
    """Run ``fn(*args)`` with JAX's transfer guard set to ``disallow`` and
    return the (block_until_ready'd) result — the single source of truth for
    the repo's "zero host round-trips after warmup" assertions.

    ``guard="all"`` disallows every implicit transfer
    (``jax.transfer_guard``); ``guard="d2h"`` disallows only device→host
    (``jax.transfer_guard_device_to_host``) — the one-shard_map-region
    guarantee. With ``warmup`` the first call (compilation, which may
    legally sync) happens outside the guard."""
    if guard == "all":
        ctx = jax.transfer_guard("disallow")
    elif guard == "d2h":
        ctx = jax.transfer_guard_device_to_host("disallow")
    else:
        raise ValueError(f"guard must be 'all' or 'd2h', got {guard!r}")
    if warmup:
        jax.block_until_ready(fn(*args))
    with ctx:
        out = fn(*args)
        jax.block_until_ready(out)
    return out
