"""Interval lattice for the scale-safety abstract interpreter.

The domain is a single product lattice value per traced array:

    Ival(lo, hi, known)

``lo``/``hi`` bound every element of the array with exact Python numbers
(unbounded ints, or floats including ``±inf``); ``known=False`` marks a
value whose bounds are a *fallback* (unmodelled primitive, widened loop
carry) — such values still flow, but never fire findings, so the analyzer
stays sound against false positives at the cost of false negatives.

Everything here is pure Python on scalars (no JAX), so the transfer
functions are unit-testable against brute-force enumeration over tiny
concrete ranges (``tests/test_absint.py``).

Dtype helpers capture the two facts the W-rules need:

* integer range + signedness (``int_bounds`` / ``is_signed_int``) — W1
  fires when a *signed* interval escapes its dtype; unsigned arithmetic
  wraps (two's-complement semantics, see ``wrap_unsigned``), which keeps
  the Morton magic-number multiplies silent;
* float mantissa width (``mantissa_bits`` / ``ulp_at``) — W2 fires when
  a quantizing op sees magnitudes at which the ulp spacing exceeds 1
  (the ``round(BIG/L)*L == BIG`` min-image collapse).
"""
from __future__ import annotations

import dataclasses
import math

__all__ = [
    "Ival",
    "TOP",
    "const",
    "join",
    "meet",
    "add",
    "sub",
    "mul",
    "div",
    "rem",
    "neg",
    "iabs",
    "imin",
    "imax",
    "floor_op",
    "ceil_op",
    "round_op",
    "truncate",
    "bit_and",
    "bit_or",
    "bit_xor",
    "shift_left",
    "shift_right",
    "scale_by_count",
    "monotonic",
    "int_bounds",
    "is_signed_int",
    "is_unsigned_int",
    "is_float",
    "mantissa_bits",
    "ulp_at",
    "wrap_unsigned",
    "dtype_top",
]


@dataclasses.dataclass(frozen=True)
class Ival:
    """Bounds on every element of one traced array. Exact Python numbers;
    ``known=False`` means the bounds are a fallback and must not fire
    findings."""
    lo: float
    hi: float
    known: bool = True

    def __post_init__(self):
        assert self.lo <= self.hi, (self.lo, self.hi)

    def contains(self, x) -> bool:
        return self.lo <= x <= self.hi

    def overlaps(self, other: "Ival") -> bool:
        return self.lo <= other.hi and other.lo <= self.hi

    def maxmag(self) -> float:
        return max(abs(self.lo), abs(self.hi))

    def is_point(self) -> bool:
        return self.lo == self.hi


TOP = Ival(-math.inf, math.inf, known=False)


def const(x) -> Ival:
    x = float(x) if isinstance(x, float) else x
    return Ival(x, x, known=True)


def join(a: Ival, b: Ival) -> Ival:
    return Ival(min(a.lo, b.lo), max(a.hi, b.hi), a.known and b.known)


def meet(a: Ival, b: Ival):
    """Intersection, or None when empty (an infeasible refinement branch)."""
    lo, hi = max(a.lo, b.lo), min(a.hi, b.hi)
    if lo > hi:
        return None
    return Ival(lo, hi, a.known and b.known)


def _k(*ivals: Ival) -> bool:
    return all(v.known for v in ivals)


def add(a: Ival, b: Ival) -> Ival:
    return Ival(a.lo + b.lo, a.hi + b.hi, _k(a, b))


def sub(a: Ival, b: Ival) -> Ival:
    return Ival(a.lo - b.hi, a.hi - b.lo, _k(a, b))


def _mul1(x, y):
    if (x == 0 or y == 0):
        return 0
    if math.isinf(x) or math.isinf(y):
        return math.inf if (x > 0) == (y > 0) else -math.inf
    return x * y


def mul(a: Ival, b: Ival) -> Ival:
    cs = [_mul1(x, y) for x in (a.lo, a.hi) for y in (b.lo, b.hi)]
    return Ival(min(cs), max(cs), _k(a, b))


def div(a: Ival, b: Ival) -> Ival:
    """Quotient bounds; a denominator interval containing 0 yields
    unbounded (but still *known*) magnitude."""
    if b.lo <= 0 <= b.hi:
        return Ival(-math.inf, math.inf, _k(a, b))
    cs = []
    for x in (a.lo, a.hi):
        for y in (b.lo, b.hi):
            cs.append(-math.inf if math.isinf(x) and x < 0 else
                      math.inf if math.isinf(x) else x / y)
    return Ival(min(cs), max(cs), _k(a, b))


def rem(a: Ival, b: Ival) -> Ival:
    """|a % b| < max|b|, sign follows the dividend (C/XLA semantics)."""
    m = b.maxmag()
    if math.isinf(m):
        return Ival(-math.inf, math.inf, _k(a, b))
    lo = -m if a.lo < 0 else 0
    hi = m if a.hi > 0 else 0
    # |r| <= |a|, so the dividend clamps the bound on ITS side of zero
    # only (an all-negative dividend still admits r == 0: -6 % -2 == 0).
    if a.lo <= 0 and not math.isinf(a.lo):
        lo = max(lo, a.lo)
    if a.hi >= 0 and not math.isinf(a.hi):
        hi = min(hi, a.hi)
    return Ival(lo, hi, _k(a, b))


def neg(a: Ival) -> Ival:
    return Ival(-a.hi, -a.lo, a.known)


def iabs(a: Ival) -> Ival:
    if a.lo >= 0:
        return a
    if a.hi <= 0:
        return neg(a)
    return Ival(0, max(-a.lo, a.hi), a.known)


def imin(a: Ival, b: Ival) -> Ival:
    return Ival(min(a.lo, b.lo), min(a.hi, b.hi), _k(a, b))


def imax(a: Ival, b: Ival) -> Ival:
    return Ival(max(a.lo, b.lo), max(a.hi, b.hi), _k(a, b))


def floor_op(a: Ival) -> Ival:
    return Ival(_floor(a.lo), _floor(a.hi), a.known)


def ceil_op(a: Ival) -> Ival:
    return Ival(_ceil(a.lo), _ceil(a.hi), a.known)


def round_op(a: Ival) -> Ival:
    return Ival(_floor(a.lo), _ceil(a.hi), a.known)


def truncate(a: Ival) -> Ival:
    """Round-toward-zero (float→int convert semantics)."""
    lo = _ceil(a.lo) if a.lo < 0 else _floor(a.lo)
    hi = _ceil(a.hi) if a.hi < 0 else _floor(a.hi)
    return Ival(lo, hi, a.known)


def _floor(x):
    return x if math.isinf(x) else math.floor(x)


def _ceil(x):
    return x if math.isinf(x) else math.ceil(x)


def _pow2_cover(hi) -> float:
    """Smallest 2^k - 1 >= hi (bound for bitwise or/xor of nonnegatives)."""
    if math.isinf(hi):
        return math.inf
    return (1 << max(int(hi), 0).bit_length()) - 1


def bit_and(a: Ival, b: Ival) -> Ival:
    """x & mask with a nonnegative mask lands in [0, mask] regardless of
    the sign of x (two's complement) — the mask-recovery rule that keeps
    Morton bit-surgery precise."""
    if b.lo >= 0 and not math.isinf(b.hi):
        hi = b.hi if a.lo < 0 or math.isinf(a.hi) else min(a.hi, b.hi)
        return Ival(0, hi, _k(a, b) if a.known or b.known else False)
    if a.lo >= 0 and not math.isinf(a.hi):
        return bit_and(b, a)
    return Ival(-math.inf, math.inf, False)


def bit_or(a: Ival, b: Ival) -> Ival:
    if a.lo >= 0 and b.lo >= 0:
        return Ival(0, _pow2_cover(max(a.hi, b.hi)), _k(a, b))
    return Ival(-math.inf, math.inf, False)


def bit_xor(a: Ival, b: Ival) -> Ival:
    if a.lo >= 0 and b.lo >= 0:
        return Ival(0, _pow2_cover(max(a.hi, b.hi)), _k(a, b))
    return Ival(-math.inf, math.inf, False)


def shift_left(a: Ival, s: Ival) -> Ival:
    if s.lo < 0 or math.isinf(s.hi):
        return Ival(-math.inf, math.inf, False)
    cs = [_mul1(x, 1 << int(k)) for x in (a.lo, a.hi)
          for k in (s.lo, s.hi)]
    return Ival(min(cs), max(cs), _k(a, s))


def shift_right(a: Ival, s: Ival, *, arithmetic: bool) -> Ival:
    if s.lo < 0 or math.isinf(s.hi) or math.isinf(a.maxmag()):
        return Ival(-math.inf, math.inf, False)
    if not arithmetic and a.lo < 0:
        # logical shift of a negative reinterprets the sign bit: huge.
        return Ival(-math.inf, math.inf, False)
    cs = [x >> int(k) if isinstance(x, int) else math.floor(x / (1 << int(k)))
          for x in (int(a.lo), int(a.hi)) for k in (s.lo, s.hi)]
    return Ival(min(cs), max(cs), _k(a, s))


def scale_by_count(a: Ival, count, known: bool = True) -> Ival:
    """Bounds on a sum of ``count`` terms each in ``a`` (reduce_sum,
    cumsum, psum, scatter-add accumulation)."""
    lo = _mul1(min(a.lo, 0), count)
    hi = _mul1(max(a.hi, 0), count)
    return Ival(lo, hi, a.known and known)


def monotonic(a: Ival, f) -> Ival:
    """Transfer for a monotonically increasing scalar function."""
    return Ival(f(a.lo), f(a.hi), a.known)


# --- dtype facts -------------------------------------------------------------

_INT_BITS = {"int8": 8, "int16": 16, "int32": 32, "int64": 64,
             "uint8": 8, "uint16": 16, "uint32": 32, "uint64": 64}
_MANTISSA = {"float16": 11, "bfloat16": 8, "float32": 24, "float64": 53}


def _dname(dtype) -> str:
    return getattr(dtype, "name", str(dtype))


def is_signed_int(dtype) -> bool:
    return _dname(dtype).startswith("int")


def is_unsigned_int(dtype) -> bool:
    return _dname(dtype).startswith("uint")


def is_float(dtype) -> bool:
    return _dname(dtype) in _MANTISSA


def int_bounds(dtype):
    """(min, max) representable for an integer dtype; None otherwise."""
    name = _dname(dtype)
    bits = _INT_BITS.get(name)
    if bits is None:
        return None
    if name.startswith("u"):
        return 0, (1 << bits) - 1
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def mantissa_bits(dtype):
    """Mantissa width incl. the implicit bit; None for non-floats. Integer
    spacing is exact up to 2^mantissa_bits (2^24 for f32, 2^53 for f64)."""
    return _MANTISSA.get(_dname(dtype))


def ulp_at(mag: float, dtype) -> float:
    """Spacing between representable floats at magnitude ``mag``."""
    m = mantissa_bits(dtype)
    if m is None or mag == 0:
        return 0.0
    if math.isinf(mag):
        return math.inf
    return 2.0 ** (math.floor(math.log2(abs(mag))) + 1 - m)


def wrap_unsigned(v: Ival, dtype) -> Ival:
    """Two's-complement wrap of an unsigned result: if the true interval
    escapes the dtype it wraps — widen to the full range but stay *known*
    (deliberate wraparound, e.g. Morton magic multiplies, is not a bug)."""
    bounds = int_bounds(dtype)
    if bounds is None:
        return v
    lo, hi = bounds
    if v.lo >= lo and v.hi <= hi:
        return v
    return Ival(lo, hi, v.known)


def dtype_top(dtype) -> Ival:
    """The fallback abstract value for a dtype (unknown provenance)."""
    bounds = int_bounds(dtype)
    if _dname(dtype) == "bool":
        return Ival(0, 1, False)
    if bounds is not None:
        return Ival(bounds[0], bounds[1], False)
    return Ival(-math.inf, math.inf, False)
