"""Registered jaxpr audits: the repo's device pipelines, each traced and
checked against the Layer-1 rules.

One entry per audited entry point (the CSR device path, the DBSCAN
variants, the fused sharded halo pipeline, the Pallas kernel wrappers,
and the serving tier's fixed-bucket recompile premise). The registry is
consumed two ways:

* ``pytest`` — ``tests/test_staticcheck.py`` parametrizes one test per
  audit, so a regression names the entry point that broke;
* the CLI — ``python -m repro.staticcheck --jaxpr [--fast]`` runs them
  all and folds the findings into the JSON report.

Budgets are sized per entry point as "the dense object this pipeline
must NOT stage": ``q x max_count`` for CSR fills, ``n x n`` for
neighbor pipelines.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.staticcheck.findings import Finding
from repro.staticcheck.jaxpr_audit import (audit_jaxpr, bounded_recompiles,
                                           jaxpr_op_signature,
                                           no_dense_intermediate,
                                           no_host_transfer)

__all__ = ["Audit", "REGISTERED_AUDITS", "run_registered_audits"]


@dataclasses.dataclass(frozen=True)
class Audit:
    name: str
    run: Callable[[bool], list[Finding]]  # fast -> findings


def _skewed_workload(n: int, nq: int):
    """One fat query matching every point, the rest matching none — the
    workload where a dense ``(q, max_count)`` fill buffer is maximal."""
    import jax.numpy as jnp
    from repro.core.bvh import build_bvh
    from repro.core.geometry import scene_bounds
    from repro.core.query import within

    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.uniform(0, 1, (n, 3)).astype(np.float32))
    lo, hi = scene_bounds(pts)
    bvh = build_bvh(pts, lo, hi)
    queries = np.full((nq, 3), 50.0, np.float32)
    queries[0] = 0.5
    radii = np.full((nq,), 1e-3, np.float32)
    radii[0] = 2.0
    pred = within(jnp.asarray(queries), jnp.asarray(radii))
    return bvh, pred


def _audit_query_csr_device(fast: bool) -> list[Finding]:
    from repro.core.query import query_csr_device

    n = nq = 128 if fast else 256
    bvh, pred = _skewed_workload(n, nq)
    dense = nq * n  # the forbidden (q, max_count) buffer
    return audit_jaxpr(
        lambda b, p: query_csr_device(b, p, capacity=n + 64, chunk=16),
        (bvh, pred),
        [no_dense_intermediate(dense), no_host_transfer()],
        name="query_csr_device")


def _clustered(n: int):
    import jax.numpy as jnp
    from repro.data.pipeline import hacc_benchmark_epsilon, make_clustered_points

    pts = make_clustered_points(np.random.default_rng(0), n)
    eps = hacc_benchmark_epsilon(1.0, n)
    return jnp.asarray(pts), float(eps)


def _audit_fdbscan(fast: bool) -> list[Finding]:
    from repro.core.dbscan import fdbscan

    n = 128 if fast else 512
    pts, eps = _clustered(n)
    return audit_jaxpr(
        lambda p: fdbscan(p, eps, 2), (pts,),
        [no_dense_intermediate(n * n), no_host_transfer()],
        name="fdbscan")


def _audit_fdbscan_pair(fast: bool) -> list[Finding]:
    from repro.core.dbscan import fdbscan_pair

    n = 128 if fast else 512
    pts, eps = _clustered(n)
    return audit_jaxpr(
        lambda p: fdbscan_pair(p, eps, 2), (pts,),
        [no_dense_intermediate(n * n), no_host_transfer()],
        name="fdbscan_pair")


def _audit_halo_pipeline_sharded(fast: bool) -> list[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.halos import halo_pipeline_sharded

    n = 128 if fast else 256
    ndev = jax.local_device_count()
    try:
        mesh = jax.make_mesh((ndev,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except (AttributeError, TypeError):
        mesh = jax.make_mesh((ndev,), ("data",))
    rng = np.random.default_rng(7)
    pts = np.sort(rng.uniform(0, 1, (n, 3)).astype(np.float32), axis=0)
    vel = rng.standard_normal((n, 3)).astype(np.float32)
    return audit_jaxpr(
        lambda p, v: halo_pipeline_sharded(
            p, v, 0.05, 2, mesh=mesh, capacity=64, halo_cap=64, min_count=2),
        (jnp.asarray(pts), jnp.asarray(vel)),
        [no_dense_intermediate(n * n), no_host_transfer()],
        name="halo_pipeline_sharded")


def _audit_kernel_pairwise(fast: bool) -> list[Finding]:
    import jax.numpy as jnp
    from repro.kernels import ops

    m = n = 256 if fast else 512
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(0, 1, (m, 3)), jnp.float32)
    # budget: the full (m, n) pairwise mask — the kernel must stay tiled
    return audit_jaxpr(
        lambda a: ops.eps_neighbor_counts(a, a, 0.1), (x,),
        [no_dense_intermediate(m * n), no_host_transfer()],
        name="eps_neighbor_counts")


def _audit_serving_buckets(fast: bool) -> list[Finding]:
    """The serving tier's fixed-bucket premise (ROADMAP item 4): a sweep of
    arbitrary request sizes, padded to power-of-two buckets, must hit a
    bounded number of compiled shapes."""
    import jax.numpy as jnp
    from repro.core.bvh import build_bvh
    from repro.core.geometry import scene_bounds
    from repro.core.query import query_count, within

    n = 64
    rng = np.random.default_rng(11)
    pts = jnp.asarray(rng.uniform(0, 1, (n, 3)).astype(np.float32))
    lo, hi = scene_bounds(pts)
    bvh = build_bvh(pts, lo, hi)

    def bucketed(nq: int):
        cap = 1 << max(2, (nq - 1).bit_length())   # next power of two, >= 4
        q = np.full((cap, 3), 50.0, np.float32)    # pad with far-away queries
        q[:nq] = rng.uniform(0, 1, (nq, 3)).astype(np.float32)
        return (jnp.asarray(q),)

    sizes = [1, 2, 3, 4, 5, 7, 8] if fast else list(range(1, 33))
    sweep = [bucketed(nq) for nq in sizes]
    cap = 3 if fast else 5  # buckets {4, 8} fast; {4, 8, 16, 32} full
    return bounded_recompiles(
        lambda q: query_count(bvh, within(q, 0.1)), sweep, cap,
        name="serving_bucketed_query")


def _audit_stats_path_identity(fast: bool) -> list[Finding]:
    """The obs layer's zero-cost contract: with ``with_stats=False`` the
    engine must stage the exact pre-obs program. Compares the live
    ``query_count`` path against the frozen twin snapshot in
    ``staticcheck/frozen_query.py`` by op-level jaxpr signature, for both
    instrumented traversal cores (rope + stack)."""
    from repro.core.query import query_count
    from repro.staticcheck.frozen_query import (frozen_count_stack,
                                                frozen_count_stackless)

    n, nq = (128, 32) if fast else (256, 64)
    bvh, pred = _skewed_workload(n, nq)
    findings: list[Finding] = []
    for backend, frozen in (("stackless", frozen_count_stackless),
                            ("stack", frozen_count_stack)):
        live = jaxpr_op_signature(
            lambda b, p: query_count(b, p, backend=backend), (bvh, pred))
        ref = jaxpr_op_signature(frozen, (bvh, pred))
        if live == ref:
            continue
        divergence = next(
            (i for i, (a, b) in enumerate(zip(live, ref)) if a != b),
            min(len(live), len(ref)))
        findings.append(Finding(
            rule="stats-path-identity",
            path=f"<jaxpr:query_count[{backend}]>", line=0,
            message=(
                f"with_stats=False path diverged from the frozen pre-obs "
                f"jaxpr at op {divergence} "
                f"(live {len(live)} ops vs frozen {len(ref)}; "
                f"live[{divergence}]="
                f"{live[divergence] if divergence < len(live) else '<end>'}, "
                f"frozen[{divergence}]="
                f"{ref[divergence] if divergence < len(ref) else '<end>'}): "
                f"counter arithmetic is leaking into the stats-off hot "
                f"path, or the engine changed without updating "
                f"staticcheck/frozen_query.py")))
    return findings


def _audit_obs_stats(fast: bool) -> list[Finding]:
    """The stats-ON entry points under the existing device-discipline
    rules: instrumented traversal must still stage no host transfer and no
    dense buffer (the counters ride the loop carry)."""
    from repro.core.query import query_count

    n, nq = (128, 32) if fast else (256, 64)
    bvh, pred = _skewed_workload(n, nq)
    findings: list[Finding] = []
    for backend in ("stackless", "stack"):
        findings.extend(audit_jaxpr(
            lambda b, p: query_count(b, p, backend=backend, with_stats=True),
            (bvh, pred),
            [no_dense_intermediate(nq * n), no_host_transfer()],
            name=f"query_count_stats_{backend}"))
    return findings


def _audit_wavefront_backend(fast: bool) -> list[Finding]:
    """backend='pallas' under the device-discipline rules: the wavefront
    count pass and the resumable chunked CSR fill must stage no host
    transfer and no dense buffer — the audit walker descends into the
    pallas_call kernel jaxpr, so the kernel body is covered too."""
    from repro.core.query import query_count, query_csr_device

    n = nq = 128 if fast else 256
    bvh, pred = _skewed_workload(n, nq)
    dense = nq * n
    findings = audit_jaxpr(
        lambda b, p: query_count(b, p, backend="pallas"),
        (bvh, pred),
        [no_dense_intermediate(dense), no_host_transfer()],
        name="query_count_pallas")
    findings += audit_jaxpr(
        lambda b, p: query_csr_device(b, p, capacity=n + 64, chunk=16,
                                      backend="pallas"),
        (bvh, pred),
        [no_dense_intermediate(dense), no_host_transfer()],
        name="query_csr_device_pallas")
    return findings


REGISTERED_AUDITS: list[Audit] = [
    Audit("query_csr_device", _audit_query_csr_device),
    Audit("kernels/wavefront_backend", _audit_wavefront_backend),
    Audit("fdbscan", _audit_fdbscan),
    Audit("fdbscan_pair", _audit_fdbscan_pair),
    Audit("halo_pipeline_sharded", _audit_halo_pipeline_sharded),
    Audit("kernels/eps_neighbor_counts", _audit_kernel_pairwise),
    Audit("serving/bucketed_recompiles", _audit_serving_buckets),
    Audit("obs/stats_path_identity", _audit_stats_path_identity),
    Audit("obs/query_stats_device", _audit_obs_stats),
]


def run_registered_audits(fast: bool = False) -> tuple[list[Finding], list[str]]:
    findings: list[Finding] = []
    names: list[str] = []
    for audit in REGISTERED_AUDITS:
        names.append(audit.name)
        findings.extend(audit.run(fast))
    return findings, names
