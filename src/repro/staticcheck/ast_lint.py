"""Layer 2: AST lint over ``src/repro`` — the repo's architecture rules as
machine-checked gates.

The rules encode contracts that previously lived only in docstrings and
ROADMAP notes:

* **R1** ``bvh-loop-outside-engine`` — no ``jax.lax.while_loop`` whose
  cond/body indexes BVH traversal arrays (``rope`` / ``left_child`` /
  ``right_child`` / ``node_lo`` / ``node_hi``) outside
  ``core/query.py``. This is the PR 4 engine contract: every traversal
  goes through the unified query engine, so engine-level improvements
  (Morton sorting, the Pallas wavefront backend) reach every client.
  Union-find fixpoints (``dbscan.py`` / ``emst.py``) index no BVH arrays
  and stay legal.
* **R2** ``unguarded-shard-map-jit`` — no ``jax.jit`` wrapping a function
  that opens a ``shard_map`` region, except inside ``core/distributed.py``
  (whose ``_maybe_jit`` / ``_jit_ok`` gate exists because XLA:CPU's
  busy-spin collective rendezvous deadlocks jitted shard_map programs
  when simulated devices outnumber host cores).
* **R3** ``unchecked-csr-overflow`` — every ``DeviceCsr`` /
  ``BufferedCsr`` producer call-site must consume ``.overflowed`` (or
  return the result to its caller, which moves the obligation there), or
  opt out with ``# staticcheck: overflow-ok``. Fixed-capacity protocols
  that silently drop hits are how wrong answers happen.
* **R4** ``unguarded-minimage-fold`` — no ``round(x / period) * period``
  minimum-image fold without an ``abs(...) > 2 * period`` guard (or
  ``# staticcheck: minimage-ok``). The f32 trap from ROADMAP item 3:
  with BIG padding, ``round(BIG/L)*L == BIG`` aliases padded rows to
  distance zero.

Pragmas: ``# staticcheck: <token>`` on the flagged line (or the line
directly above) suppresses the matching rule; ``# staticcheck: ignore``
suppresses any rule on that line.
"""
from __future__ import annotations

import ast
import pathlib
import re

from repro.staticcheck.findings import Finding

__all__ = [
    "BVH_NODE_FIELDS",
    "CSR_PRODUCERS",
    "RULES",
    "lint_source",
    "lint_paths",
]

# The traversal-structure arrays: a hand-rolled walk must read the node
# links (rope/children) or the node boxes to descend. ``leaf_perm`` is
# deliberately NOT here — clients legally reindex results through it
# (e.g. fdbscan_pair's union bookkeeping) without traversing anything.
BVH_NODE_FIELDS = frozenset({
    "rope", "left_child", "right_child", "node_lo", "node_hi",
})

CSR_PRODUCERS = frozenset({
    "query_csr", "query_csr_device", "query_csr_buffered",
    "sharded_query_csr", "sharded_neighbor_csr", "raycast_all",
})

# Files exempt per rule (matched as posix-path suffixes).
# R1: the homes of BVH loops — the engine's vmapped cores and the blessed
# Pallas wavefront kernel module (the engine's backend="pallas"). Any other
# kernels/ module hand-rolling a rope loop still fires.
_ENGINE_FILES = ("core/query.py", "kernels/wavefront.py")
_JIT_GATE_FILES = ("core/distributed.py",)  # R2: home of _maybe_jit/_jit_ok

_PRAGMA_RE = re.compile(r"#\s*staticcheck:\s*([\w,\s-]+)")


def _pragma_lines(source: str) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            out[i] = {tok.strip() for tok in m.group(1).replace(",", " ").split()
                      if tok.strip()}
    return out


def _suppressed(pragmas: dict[int, set[str]], node: ast.AST, token: str) -> bool:
    lines = range(node.lineno - 1, getattr(node, "end_lineno", node.lineno) + 1)
    for ln in lines:
        toks = pragmas.get(ln, ())
        if token in toks or "ignore" in toks:
            return True
    return False


def _dotted(node: ast.AST) -> str:
    """'jax.lax.while_loop' for an Attribute chain, 'f' for a Name."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _tail(name: str) -> str:
    return name.rsplit(".", 1)[-1]


def _parents(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    par: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            par[child] = node
    return par


def _enclosing_functions(node: ast.AST, parents) -> list[ast.AST]:
    """FunctionDefs containing ``node``, innermost first."""
    out = []
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.append(cur)
        cur = parents.get(cur)
    return out


def _matches(path: str, suffixes: tuple[str, ...]) -> bool:
    p = pathlib.PurePath(path).as_posix()
    return any(p.endswith(s) for s in suffixes)


# --- R1: BVH traversal loops outside the engine -----------------------------

def _resolve_local_fn(name: str, scopes: list[ast.AST]) -> ast.AST | None:
    """Find a def/lambda bound to ``name`` in the given scopes (innermost
    first; each scope searched one level deep plus its nested defs)."""
    for scope in scopes:
        for node in ast.walk(scope):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == name:
                return node
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Lambda):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == name:
                        return node.value
    return None


def _indexes_bvh_fields(fn_node: ast.AST, scopes: list[ast.AST],
                        _seen: set | None = None) -> bool:
    """Does this function subscript a BVH node array (``x.rope[...]``),
    directly or through a locally-defined helper it calls?"""
    seen = _seen if _seen is not None else set()
    if id(fn_node) in seen:
        return False
    seen.add(id(fn_node))
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.value, ast.Attribute) \
                and node.value.attr in BVH_NODE_FIELDS:
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            callee = _resolve_local_fn(node.func.id, scopes)
            if callee is not None and _indexes_bvh_fields(callee, scopes, seen):
                return True
    return False


def _rule_r1(tree, source, path, pragmas, parents) -> list[Finding]:
    if _matches(path, _ENGINE_FILES):
        return []
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _tail(_dotted(node.func)) == "while_loop"):
            continue
        if _suppressed(pragmas, node, "bvh-loop-ok"):
            continue
        scopes = _enclosing_functions(node, parents) + [tree]
        hit = False
        for arg in node.args[:2]:  # cond_fun, body_fun
            fn_node = arg if isinstance(arg, ast.Lambda) else (
                _resolve_local_fn(arg.id, scopes)
                if isinstance(arg, ast.Name) else None)
            if fn_node is not None and _indexes_bvh_fields(fn_node, scopes):
                hit = True
                break
        if hit:
            findings.append(Finding(
                rule="R1-bvh-loop-outside-engine", path=path, line=node.lineno,
                message=("hand-rolled BVH traversal while_loop (indexes "
                         "BVH node arrays) outside core/query.py — use the "
                         "unified query engine")))
    return findings


# --- R2: jax.jit around shard_map drivers -----------------------------------

def _contains_shard_map(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and _tail(_dotted(sub.func)) == "shard_map":
            return True
    return False


def _is_jax_jit(node: ast.AST) -> bool:
    name = _dotted(node)
    return name in ("jit", "jax.jit")


def _decorator_is_jit(dec: ast.AST) -> bool:
    if _is_jax_jit(dec):
        return True
    if isinstance(dec, ast.Call):
        if _is_jax_jit(dec.func):
            return True
        # partial(jax.jit, ...) / functools.partial(jax.jit, ...)
        if _tail(_dotted(dec.func)) == "partial" and dec.args \
                and _is_jax_jit(dec.args[0]):
            return True
    return False


def _rule_r2(tree, source, path, pragmas, parents) -> list[Finding]:
    if _matches(path, _JIT_GATE_FILES):
        return []
    findings = []
    shard_fns = {node.name: node for node in ast.walk(tree)
                 if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and _contains_shard_map(node)}

    def emit(node):
        if not _suppressed(pragmas, node, "shard-jit-ok"):
            findings.append(Finding(
                rule="R2-unguarded-shard-map-jit", path=path, line=node.lineno,
                message=("jax.jit around a shard_map driver — route through "
                         "core/distributed.py's _maybe_jit/_jit_ok gate "
                         "(XLA:CPU collective-rendezvous deadlock)")))

    for name, fn in shard_fns.items():
        for dec in fn.decorator_list:
            if _decorator_is_jit(dec):
                emit(dec)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func) and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Name) and arg.id in shard_fns:
                emit(node)
            elif isinstance(arg, (ast.Lambda, ast.Call)) \
                    and _contains_shard_map(arg):
                emit(node)
    return findings


# --- R3: CSR overflow must be consumed --------------------------------------

def _rule_r3(tree, source, path, pragmas, parents) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _tail(_dotted(node.func)) in CSR_PRODUCERS):
            continue
        if _suppressed(pragmas, node, "overflow-ok"):
            continue
        parent = parents.get(node)
        # return producer(...) / lambda *: producer(...)  -> the obligation
        # moves to the caller
        if isinstance(parent, ast.Return) or (
                isinstance(parent, ast.Lambda) and parent.body is node):
            continue
        # producer(...).overflowed  -> consumed on the spot
        if isinstance(parent, ast.Attribute) and parent.attr == "overflowed":
            continue
        consumed = False
        if isinstance(parent, (ast.Assign, ast.AnnAssign)):
            targets = parent.targets if isinstance(parent, ast.Assign) \
                else [parent.target]
            names = {t.id for t in targets if isinstance(t, ast.Name)}
            scopes = _enclosing_functions(node, parents) or [tree]
            for sub in ast.walk(scopes[0]):
                if isinstance(sub, ast.Attribute) and sub.attr == "overflowed" \
                        and isinstance(sub.value, ast.Name) \
                        and sub.value.id in names:
                    consumed = True
                    break
        if not consumed:
            findings.append(Finding(
                rule="R3-unchecked-csr-overflow", path=path, line=node.lineno,
                message=(f"{_tail(_dotted(node.func))}(...) result never "
                         f"consumes .overflowed — check it or annotate "
                         f"'# staticcheck: overflow-ok'")))
    return findings


# --- R4: guarded minimum-image folds ----------------------------------------

_ROUND_FNS = frozenset({"round", "rint"})
_ABS_FNS = frozenset({"abs", "absolute", "fabs"})


def _is_two(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and node.value in (2, 2.0)


def _has_minimage_guard(scope: ast.AST) -> bool:
    """An ``abs(...) <cmp> 2 * period``-shaped comparison anywhere in scope."""
    for node in ast.walk(scope):
        if not isinstance(node, ast.Compare):
            continue
        has_abs = any(
            isinstance(sub, ast.Call) and _tail(_dotted(sub.func)) in _ABS_FNS
            for sub in ast.walk(node))
        has_2x = any(
            isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mult)
            and (_is_two(sub.left) or _is_two(sub.right))
            for sub in ast.walk(node))
        if has_abs and has_2x:
            return True
    return False


def _rule_r4(tree, source, path, pragmas, parents) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _tail(_dotted(node.func)) in _ROUND_FNS
                and len(node.args) == 1
                and isinstance(node.args[0], ast.BinOp)
                and isinstance(node.args[0].op, ast.Div)):
            continue
        if _suppressed(pragmas, node, "minimage-ok"):
            continue
        period = ast.dump(node.args[0].right)
        scopes = _enclosing_functions(node, parents)
        scope = scopes[0] if scopes else tree
        # It is a min-image fold only if the rounded quotient is folded
        # back by the SAME period (a `* period` in the same scope).
        folds_back = any(
            isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mult)
            and (ast.dump(sub.left) == period or ast.dump(sub.right) == period)
            for sub in ast.walk(scope))
        if folds_back and not _has_minimage_guard(scope):
            findings.append(Finding(
                rule="R4-unguarded-minimage-fold", path=path, line=node.lineno,
                message=("round(x / period) * period min-image fold without "
                         "an abs(diff) > 2 * period guard — f32 padding "
                         "aliases to distance 0 (ROADMAP item 3)")))
    return findings


RULES = {
    "R1": _rule_r1,
    "R2": _rule_r2,
    "R3": _rule_r3,
    "R4": _rule_r4,
}


def lint_source(source: str, path: str = "<string>", *,
                rules=None) -> list[Finding]:
    """Lint one source string. ``rules``: iterable of rule keys ("R1"…)
    to run, default all."""
    tree = ast.parse(source, filename=path)
    pragmas = _pragma_lines(source)
    parents = _parents(tree)
    findings: list[Finding] = []
    for key in (rules or sorted(RULES)):
        findings.extend(RULES[key](tree, source, path, pragmas, parents))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_paths(paths, *, rules=None) -> tuple[list[Finding], int]:
    """Lint every ``.py`` file under the given files/directories. Returns
    (findings, number_of_files_checked)."""
    files: list[pathlib.Path] = []
    for p in map(pathlib.Path, paths):
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    findings: list[Finding] = []
    for f in files:
        findings.extend(lint_source(f.read_text(), str(f), rules=rules))
    return findings, len(files)
