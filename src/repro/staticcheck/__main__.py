"""CLI: ``python -m repro.staticcheck [paths...] [--jaxpr] [--absint]
[--fast] [--json REPORT] [--absint-json REPORT] [--rules R1,R3]``.

Runs the AST lint over the given paths (default: the installed
``repro`` package source, i.e. ``src/repro``) and, with ``--jaxpr``,
the registered jaxpr audits; with ``--absint``, the scale-safety
abstract-interpreter audits (W1 index-width / W2 precision / W3 bounds
& routes at symbolic N — see ``repro.staticcheck.absint``). Prints one
``file:line: [rule] message`` line per finding, writes the JSON
report(s), and exits nonzero iff any finding fired — the CI gate.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import repro
from repro.staticcheck.ast_lint import RULES, lint_paths
from repro.staticcheck.findings import write_report


def _default_root() -> str:
    # ``repro`` is a namespace package: locate it via __path__, not __file__.
    return str(pathlib.Path(next(iter(repro.__path__))).resolve())


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.staticcheck")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: src/repro)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated AST rule subset, e.g. R1,R3")
    ap.add_argument("--jaxpr", action="store_true",
                    help="also run the registered jaxpr audits (traces the "
                         "repo's device pipelines)")
    ap.add_argument("--absint", action="store_true",
                    help="run the scale-safety abstract-interpreter audits "
                         "(index-width / precision / route invariants at "
                         "symbolic exascale N)")
    ap.add_argument("--fast", action="store_true",
                    help="smaller problem sizes for the jaxpr audits; skips "
                         "the slowest absint trace")
    ap.add_argument("--json", default="staticcheck_report.json",
                    help="JSON report path (default: %(default)s)")
    ap.add_argument("--absint-json", default="absint_report.json",
                    help="absint JSON report path, written only with "
                         "--absint (default: %(default)s)")
    args = ap.parse_args(argv)

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            ap.error(f"unknown rules {unknown}; available: {sorted(RULES)}")

    paths = args.paths or [_default_root()]
    findings, checked = lint_paths(paths, rules=rules)

    audit_names: list[str] = []
    if args.jaxpr:
        from repro.staticcheck.registry import run_registered_audits
        jf, audit_names = run_registered_audits(fast=args.fast)
        findings = findings + jf

    absint_names: list[str] = []
    if args.absint:
        import dataclasses as _dc
        import json as _json

        from repro.staticcheck.absint_registry import run_absint_audits
        af, reports = run_absint_audits(fast=args.fast)
        findings = findings + af
        absint_names = [r.name for r in reports]
        pathlib.Path(args.absint_json).write_text(_json.dumps({
            "ok": not af,
            "entrypoints": [{
                "name": r.name,
                "values_analyzed": r.values_analyzed,
                "eqns_visited": r.eqns_visited,
                "unknown_prims": r.unknown_prims,
                "collectives": len(r.collectives),
                "findings": [_dc.asdict(f) for f in r.findings],
            } for r in reports],
        }, indent=2) + "\n")

    for f in findings:
        print(f)
    write_report(args.json, findings, checked_files=checked,
                 jaxpr_audits=audit_names)
    summary = (f"staticcheck: {len(findings)} finding(s) over {checked} "
               f"file(s)")
    if audit_names:
        summary += f" + {len(audit_names)} jaxpr audit(s)"
    if absint_names:
        summary += (f" + {len(absint_names)} absint audit(s) "
                    f"-> {args.absint_json}")
    print(summary + f"; report -> {args.json}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
