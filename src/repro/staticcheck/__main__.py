"""CLI: ``python -m repro.staticcheck [paths...] [--jaxpr] [--fast]
[--json REPORT] [--rules R1,R3]``.

Runs the AST lint over the given paths (default: the installed
``repro`` package source, i.e. ``src/repro``) and, with ``--jaxpr``,
the registered jaxpr audits. Prints one ``file:line: [rule] message``
line per finding, writes the JSON report, and exits nonzero iff any
finding fired — the CI gate.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import repro
from repro.staticcheck.ast_lint import RULES, lint_paths
from repro.staticcheck.findings import write_report


def _default_root() -> str:
    # ``repro`` is a namespace package: locate it via __path__, not __file__.
    return str(pathlib.Path(next(iter(repro.__path__))).resolve())


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.staticcheck")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: src/repro)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated AST rule subset, e.g. R1,R3")
    ap.add_argument("--jaxpr", action="store_true",
                    help="also run the registered jaxpr audits (traces the "
                         "repo's device pipelines)")
    ap.add_argument("--fast", action="store_true",
                    help="smaller problem sizes for the jaxpr audits")
    ap.add_argument("--json", default="staticcheck_report.json",
                    help="JSON report path (default: %(default)s)")
    args = ap.parse_args(argv)

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            ap.error(f"unknown rules {unknown}; available: {sorted(RULES)}")

    paths = args.paths or [_default_root()]
    findings, checked = lint_paths(paths, rules=rules)

    audit_names: list[str] = []
    if args.jaxpr:
        from repro.staticcheck.registry import run_registered_audits
        jf, audit_names = run_registered_audits(fast=args.fast)
        findings = findings + jf

    for f in findings:
        print(f)
    write_report(args.json, findings, checked_files=checked,
                 jaxpr_audits=audit_names)
    summary = (f"staticcheck: {len(findings)} finding(s) over {checked} "
               f"file(s)")
    if audit_names:
        summary += f" + {len(audit_names)} jaxpr audit(s)"
    print(summary + f"; report -> {args.json}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
