"""Finding records and the JSON report shared by both staticcheck layers.

A finding is one rule violation, anchored to ``file:line`` for the AST
layer or to ``<jaxpr:entrypoint>`` for jaxpr audits. The CLI
(``python -m repro.staticcheck``) serializes findings into a JSON report
and exits nonzero when any exist, so CI can gate on them.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation."""
    rule: str      # e.g. "R1-bvh-loop-outside-engine", "no-dense-intermediate"
    path: str      # source file, or "<jaxpr:NAME>" for traced audits
    line: int      # 1-based; 0 when the finding has no source anchor
    message: str   # human-readable explanation

    def location(self) -> str:
        return f"{self.path}:{self.line}" if self.line else self.path

    def __str__(self) -> str:  # the CLI's one-line format
        return f"{self.location()}: [{self.rule}] {self.message}"


def report_dict(findings: list[Finding], *, checked_files: int = 0,
                jaxpr_audits: list[str] | None = None) -> dict:
    return {
        "ok": not findings,
        "checked_files": checked_files,
        "jaxpr_audits": jaxpr_audits or [],
        "findings": [dataclasses.asdict(f) for f in findings],
    }


def write_report(path: str | pathlib.Path, findings: list[Finding], *,
                 checked_files: int = 0,
                 jaxpr_audits: list[str] | None = None) -> None:
    pathlib.Path(path).write_text(json.dumps(
        report_dict(findings, checked_files=checked_files,
                    jaxpr_audits=jaxpr_audits), indent=2) + "\n")
