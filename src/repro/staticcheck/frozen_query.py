"""Frozen pre-obs twin of the engine's spatial query path.

This module is a VERBATIM snapshot of what ``core/query.py`` staged for a
``query_count(bvh, within(...))`` call BEFORE the observability layer
added ``with_stats=`` (the ``_one_stackless`` / ``_one_stack`` cores, the
``Within`` predicate functions, the fused leaf callback wrapper, the
count protocol's callback). The ``stats_path_identity`` audit traces both
this twin and the live engine with ``with_stats=False`` and asserts their
jaxprs are op-for-op identical — the machine check that observability is
zero-cost when disabled (no counter arithmetic leaks into the hot path).

Do NOT refactor this file to track engine changes mechanically: it only
moves when the engine's *stats-off* program intentionally changes, and
such a change must be a conscious decision (update both, re-run
``python -m repro.staticcheck --jaxpr``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bvh import Bvh, SENTINEL
from repro.core.geometry import point_aabb_dist2
from repro.core.query import _STACK_DEPTH, Within

__all__ = ["frozen_count_stackless", "frozen_count_stack"]


def _frozen_one_stackless(bvh: Bvh, q, node_fn, leaf_fn, carry0, start):
    n = bvh.num_leaves

    def cond(state):
        node, _, done = state
        return (node != SENTINEL) & ~done

    def body(state):
        node, carry, done = state
        is_leaf = node >= n - 1
        sorted_idx = node - (n - 1)
        carry_leaf, done_leaf = leaf_fn(
            q, carry, bvh.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)], sorted_idx)
        next_leaf = bvh.rope[node]

        hit = node_fn(q, carry, node)
        node_c = jnp.clip(node, 0, n - 2)
        next_internal = jnp.where(hit, bvh.left_child[node_c], bvh.rope[node])

        carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)
        done = jnp.where(is_leaf, done | done_leaf, done)
        node = jnp.where(is_leaf, next_leaf, next_internal)
        return node, carry, done

    _, carry, _ = jax.lax.while_loop(  # staticcheck: bvh-loop-ok (frozen twin)
        cond, body, (start, carry0, jnp.bool_(False)))
    return carry


def _frozen_one_stack(bvh: Bvh, q, node_fn, leaf_fn, carry0):
    n = bvh.num_leaves
    stack0 = jnp.full((_STACK_DEPTH,), SENTINEL, jnp.int32).at[0].set(0)

    def cond(state):
        sp, _, _, done = state
        return (sp > 0) & ~done

    def body(state):
        sp, stack, carry, done = state
        node = stack[sp - 1]
        sp = sp - 1
        is_leaf = node >= n - 1
        sorted_idx = node - (n - 1)

        carry_leaf, done_leaf = leaf_fn(
            q, carry, bvh.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)], sorted_idx)

        hit = node_fn(q, carry, node) & ~is_leaf
        node_c = jnp.clip(node, 0, n - 2)
        stack = stack.at[sp].set(jnp.where(hit, bvh.right_child[node_c], stack[sp]))
        sp_r = sp + hit.astype(jnp.int32)
        stack = stack.at[sp_r].set(jnp.where(hit, bvh.left_child[node_c], stack[sp_r]))
        sp = sp_r + hit.astype(jnp.int32)

        carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)
        done = done | (is_leaf & done_leaf)
        return sp, stack, carry, done

    _, _, carry, _ = jax.lax.while_loop(  # staticcheck: bvh-loop-ok (frozen twin)
        cond, body, (jnp.int32(1), stack0, carry0, jnp.bool_(False)))
    return carry


def _frozen_within_fns(bvh: Bvh, pred: Within):
    n = bvh.num_leaves
    geom = (pred.centers, pred.radii.astype(pred.centers.dtype) ** 2)

    def node_fn(q, carry, node):
        (_, center, r2) = q
        return point_aabb_dist2(center, bvh.node_lo[node], bvh.node_hi[node]) <= r2

    def leaf_aux(q, sorted_idx):
        (_, center, r2) = q
        leaf_node = jnp.clip(sorted_idx, 0, n - 1) + (n - 1)
        d2 = point_aabb_dist2(center, bvh.node_lo[leaf_node], bvh.node_hi[leaf_node])
        return d2, d2 <= r2

    return geom, node_fn, leaf_aux


def _frozen_count(bvh: Bvh, pred: Within, backend: str):
    geom, node_fn, leaf_aux = _frozen_within_fns(bvh, pred)
    q_count = jax.tree.leaves(geom)[0].shape[0]
    qidx = jnp.arange(q_count, dtype=jnp.int32)
    qdata = (qidx,) + geom

    def cb(count, qidx, obj, d2):
        count = count + 1
        done = jnp.bool_(False)
        return count, done

    def leaf_fn(q, carry, obj, sorted_idx):
        d2, hit = leaf_aux(q, sorted_idx)
        carry2, done2 = cb(carry, q[0], obj, d2)
        carry = jax.tree.map(lambda a, b: jnp.where(hit, a, b), carry2, carry)
        return carry, hit & done2

    carries = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (q_count,) + jnp.shape(x)), jnp.int32(0))
    if backend == "stackless":
        start_nodes = jnp.zeros((q_count,), jnp.int32)
        return jax.vmap(
            lambda q, s, c: _frozen_one_stackless(bvh, q, node_fn, leaf_fn, c, s)
        )(qdata, start_nodes, carries)
    return jax.vmap(
        lambda q, c: _frozen_one_stack(bvh, q, node_fn, leaf_fn, c)
    )(qdata, carries)


def frozen_count_stackless(bvh: Bvh, pred: Within):
    """What ``query_count(bvh, pred)`` staged pre-obs (rope backend)."""
    return _frozen_count(bvh, pred, "stackless")


def frozen_count_stack(bvh: Bvh, pred: Within):
    """What ``query_count(bvh, pred, backend='stack')`` staged pre-obs."""
    return _frozen_count(bvh, pred, "stack")
