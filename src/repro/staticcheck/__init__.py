"""``repro.staticcheck`` — the repo's performance rules as machine-checked
gates (the ReFrame idea applied to program STRUCTURE instead of timings).

Two layers:

* **jaxpr audits** (``jaxpr_audit``): trace a callable and enforce
  device-discipline invariants on every sub-jaxpr —
  ``no_dense_intermediate`` (no O(n²) staging), ``no_host_transfer``
  (no callback/device_put-class primitives in device pipelines),
  ``bounded_recompiles`` (workload sweeps stay under a compiled-shape
  cap). ``registry.REGISTERED_AUDITS`` applies them to the repo's entry
  points; ``assert_no_host_transfers`` is the runtime transfer-guard
  complement used by the tests.
* **AST lint** (``ast_lint``): repo-specific architecture rules R1–R4
  over ``src/repro`` (BVH loops only in the engine, gated shard_map
  jits, consumed CSR overflow flags, guarded min-image folds), with
  ``# staticcheck: <token>`` opt-out pragmas.

CLI::

    PYTHONPATH=src python -m repro.staticcheck            # AST lint
    PYTHONPATH=src python -m repro.staticcheck --jaxpr --fast
    PYTHONPATH=src python -m repro.staticcheck --json report.json

Exit status is nonzero iff any finding fired; the JSON report carries
``file:line`` anchors for each.
"""
from repro.staticcheck.findings import Finding, report_dict, write_report
from repro.staticcheck.jaxpr_audit import (
    assert_no_host_transfers,
    audit_jaxpr,
    bounded_recompiles,
    count_compile_signatures,
    iter_eqns,
    iter_subjaxprs,
    max_intermediate_elems,
    no_dense_intermediate,
    no_host_transfer,
)
from repro.staticcheck.ast_lint import (
    BVH_NODE_FIELDS,
    CSR_PRODUCERS,
    RULES,
    lint_paths,
    lint_source,
)
from repro.staticcheck.registry import (
    Audit,
    REGISTERED_AUDITS,
    run_registered_audits,
)

__all__ = [
    "Finding", "report_dict", "write_report",
    "assert_no_host_transfers", "audit_jaxpr", "bounded_recompiles",
    "count_compile_signatures", "iter_eqns", "iter_subjaxprs",
    "max_intermediate_elems", "no_dense_intermediate", "no_host_transfer",
    "BVH_NODE_FIELDS", "CSR_PRODUCERS", "RULES", "lint_paths", "lint_source",
    "Audit", "REGISTERED_AUDITS", "run_registered_audits",
]
