"""``repro.staticcheck`` — the repo's performance rules as machine-checked
gates (the ReFrame idea applied to program STRUCTURE instead of timings).

Three layers:

* **jaxpr audits** (``jaxpr_audit``): trace a callable and enforce
  device-discipline invariants on every sub-jaxpr —
  ``no_dense_intermediate`` (no O(n²) staging), ``no_host_transfer``
  (no callback/device_put-class primitives in device pipelines),
  ``bounded_recompiles`` (workload sweeps stay under a compiled-shape
  cap). ``registry.REGISTERED_AUDITS`` applies them to the repo's entry
  points; ``assert_no_host_transfers`` is the runtime transfer-guard
  complement used by the tests.
* **AST lint** (``ast_lint``): repo-specific architecture rules R1–R4
  over ``src/repro`` (BVH loops only in the engine, gated shard_map
  jits, consumed CSR overflow flags, guarded min-image folds), with
  ``# staticcheck: <token>`` opt-out pragmas.
* **scale-safety abstract interpreter** (``absint``): propagates a
  value interval per array through the traced jaxpr and re-reads the
  staged toy sizes as symbolic exascale N — proving the W rules below
  without ever materializing a large array.

  ====  =================  ==================================================
  rule  name               fires when (at symbolic N)
  ====  =================  ==================================================
  W1    index-width        a signed-int result escapes its dtype (int32
                           ``counts→cumsum→offsets`` past 2^31 total hits;
                           ``shard*n_local+i`` global ids; narrowing
                           converts). Unsigned arithmetic wraps silently —
                           Morton magic multiplies stay legal.
  W2    precision          a float quantization (round/floor/ceil/f→i
                           convert) sees magnitude ≥ 2^mantissa — the
                           ``round(BIG/L)*L == BIG`` min-image trap; with
                           ``precision_floor``, catastrophic cancellation.
  W3    bounds & routes    a PROMISE_IN_BOUNDS gather/scatter index not
                           provably inside the symbolic axis; ``ppermute``
                           tables that are not partial permutations;
                           collective axis names outside the enclosing mesh.
  ====  =================  ==================================================

  ``absint_registry.REGISTERED_ABSINT_AUDITS`` pins the production
  (int64-widened) configurations clean; ``SEEDED_FIXTURES`` pins each
  rule firing on the historical trap it encodes.

CLI::

    PYTHONPATH=src python -m repro.staticcheck            # AST lint
    PYTHONPATH=src python -m repro.staticcheck --jaxpr --fast
    PYTHONPATH=src python -m repro.staticcheck --absint   # scale safety
    PYTHONPATH=src python -m repro.staticcheck --json report.json

Exit status is nonzero iff any finding fired; the JSON report carries
``file:line`` anchors for each (``--absint`` also writes
``absint_report.json`` with per-entrypoint coverage counters).
"""
from repro.staticcheck.findings import Finding, report_dict, write_report
from repro.staticcheck.jaxpr_audit import (
    assert_no_host_transfers,
    audit_jaxpr,
    bounded_recompiles,
    count_compile_signatures,
    iter_eqns,
    iter_subjaxprs,
    max_intermediate_elems,
    no_dense_intermediate,
    no_host_transfer,
)
from repro.staticcheck.ast_lint import (
    BVH_NODE_FIELDS,
    CSR_PRODUCERS,
    RULES,
    lint_paths,
    lint_source,
)
from repro.staticcheck.registry import (
    Audit,
    REGISTERED_AUDITS,
    run_registered_audits,
)
from repro.staticcheck.absint import (
    AbsintReport,
    CollectiveUse,
    SymbolicScale,
    analyze,
    analyze_jaxpr,
    audit_routes,
    scale_for,
)
from repro.staticcheck.absint_registry import (
    AbsintAudit,
    REGISTERED_ABSINT_AUDITS,
    SEEDED_FIXTURES,
    absint_coverage,
    run_absint_audits,
)

__all__ = [
    "Finding", "report_dict", "write_report",
    "assert_no_host_transfers", "audit_jaxpr", "bounded_recompiles",
    "count_compile_signatures", "iter_eqns", "iter_subjaxprs",
    "max_intermediate_elems", "no_dense_intermediate", "no_host_transfer",
    "BVH_NODE_FIELDS", "CSR_PRODUCERS", "RULES", "lint_paths", "lint_source",
    "Audit", "REGISTERED_AUDITS", "run_registered_audits",
    "AbsintReport", "CollectiveUse", "SymbolicScale", "analyze",
    "analyze_jaxpr", "audit_routes", "scale_for",
    "AbsintAudit", "REGISTERED_ABSINT_AUDITS", "SEEDED_FIXTURES",
    "absint_coverage", "run_absint_audits",
]
