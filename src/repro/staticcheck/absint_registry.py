"""Registered scale-safety (absint) audits: the repo's device pipelines,
each staged at toy marker sizes and re-read at **symbolic exascale N**
(1e9 points, 64 shards, avg degree 64) by the abstract interpreter.

Two families live here:

* ``REGISTERED_ABSINT_AUDITS`` — the production configurations (int64
  index dtypes under x64 where capacity crosses 2^31). These must
  analyze CLEAN at symbolic N; any finding is a CI failure
  (``python -m repro.staticcheck --absint``). Each entry also feeds one
  parametrized test in ``tests/test_absint.py``.
* ``SEEDED_FIXTURES`` — the broken twins (int32 indices at 64e9 total
  hits, the f32 min-image fold of BIG ghost fills, an out-of-mesh
  collective route). Each must fire EXACTLY its seeded rule — they pin
  the analyzer's recall the same way the clean audits pin its precision.

Sizes are markers, not workloads: ``N_STAGE = 254`` points stage the
jaxpr, ``scale_for(N_STAGE, N_SYM)`` re-reads every shape and literal
equal to a marker at the symbolic size. Tracing stays sub-second; no
giant array is ever materialized.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.staticcheck.absint import (AbsintReport, SymbolicScale, analyze,
                                      scale_for)
from repro.staticcheck.findings import Finding
from repro.staticcheck.lattice import Ival

__all__ = [
    "AbsintAudit",
    "REGISTERED_ABSINT_AUDITS",
    "SEEDED_FIXTURES",
    "run_absint_audits",
    "absint_coverage",
    "N_STAGE",
    "N_SYM",
    "AVG_DEGREE",
    "N_SHARDS",
]

N_STAGE = 254          # staged marker size (distinct from small constants)
N_SYM = 10**9          # the paper's exascale point count
AVG_DEGREE = 64        # mean neighbors/query -> 64e9 total CSR hits
N_SHARDS = 64          # symbolic mesh width
_CSR_CAP = 318         # staged capacity marker for the CSR paths
_SHARD_CAP = 322       # staged capacity marker for the sharded path
_HALO_CAP = 33


@dataclasses.dataclass(frozen=True)
class AbsintAudit:
    """One symbolic-scale analysis of a registered entry point.

    ``run(fast)`` returns the ``AbsintReport``; ``expect_rules`` is the
    exact set of rule names that must fire (empty for the clean
    production configs). ``allow`` drops findings of the named rules
    before judging — the programmatic counterpart of the source-level
    ``# staticcheck: width-ok`` pragma for values that cannot carry one
    (they live in a traced jaxpr, not a source line).
    """
    name: str
    run: Callable[[bool], AbsintReport]
    expect_rules: tuple = ()
    allow: tuple = ()


def _points(n: int = N_STAGE):
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.random((n, 3), dtype=np.float32))


def _csr_args():
    import jax.numpy as jnp
    from repro.core.bvh import build_bvh
    from repro.core.geometry import scene_bounds
    from repro.core.query import within

    pts = _points()
    lo, hi = scene_bounds(pts)
    bvh = build_bvh(pts, lo, hi)
    pred = within(pts, 0.1)
    counts = jnp.zeros((N_STAGE,), jnp.int32)
    return bvh, pred, counts


def _csr_scale() -> SymbolicScale:
    return SymbolicScale(dims=scale_for(
        N_STAGE, N_SYM,
        {_CSR_CAP: AVG_DEGREE * N_SYM, _CSR_CAP + 1: AVG_DEGREE * N_SYM + 1}))


def _run_csr(fast: bool, index_dtype, x64: bool) -> AbsintReport:
    import jax.numpy as jnp
    from repro.core.query import query_csr_device

    bvh, pred, counts = _csr_args()
    return analyze(
        lambda b, p, c: query_csr_device(b, p, _CSR_CAP, counts=c,
                                         index_dtype=index_dtype),
        (bvh, pred, counts),
        name=f"query_csr_device[{jnp.dtype(index_dtype).name}]",
        scale=_csr_scale(),
        # per-query hit counts: anything up to the capacity marker — it is
        # the 1e9-query cumsum that must not overflow the offsets dtype
        input_ivals=[None, None, Ival(0, 2048)], x64=x64)


def _audit_csr_int64(fast: bool) -> AbsintReport:
    import jax.numpy as jnp
    return _run_csr(fast, jnp.int64, x64=True)


def _fixture_csr_int32(fast: bool) -> AbsintReport:
    import jax.numpy as jnp
    return _run_csr(fast, jnp.int32, x64=False)


def _run_dbscan(fast: bool, pair: bool) -> AbsintReport:
    from repro.core.dbscan import fdbscan, fdbscan_pair

    fn = fdbscan_pair if pair else fdbscan
    pts = _points()
    return analyze(lambda p: fn(p, 0.05, 2), (pts,),
                   name="fdbscan_pair" if pair else "fdbscan",
                   scale=SymbolicScale(dims=scale_for(N_STAGE, N_SYM)),
                   input_ivals=[Ival(0.0, 1.0)])


def _audit_fdbscan(fast: bool) -> AbsintReport:
    return _run_dbscan(fast, pair=False)


def _audit_fdbscan_pair(fast: bool) -> AbsintReport:
    return _run_dbscan(fast, pair=True)


def _audit_morton_sort(fast: bool) -> AbsintReport:
    from repro.core.geometry import scene_bounds
    from repro.core.morton import (morton64, normalize_points,
                                   sort_by_morton64)

    pts = _points()
    return analyze(
        lambda p: sort_by_morton64(*morton64(
            normalize_points(p, *scene_bounds(p)))),
        (pts,), name="morton_sort",
        scale=SymbolicScale(dims=scale_for(N_STAGE, N_SYM)),
        input_ivals=[Ival(0.0, 1.0)])


def _run_sharded(fast: bool, index_dtype, x64: bool) -> AbsintReport:
    import jax
    import jax.numpy as jnp
    from repro.core.distributed import sharded_neighbor_csr

    rng = np.random.default_rng(1)
    pts = jnp.asarray(np.sort(rng.random((N_STAGE, 3), dtype=np.float32),
                              axis=0))
    mesh = jax.make_mesh((1,), ("data",))
    dims = scale_for(N_STAGE, N_SYM,
                     {_SHARD_CAP: AVG_DEGREE * N_SYM,
                      _SHARD_CAP + 1: AVG_DEGREE * N_SYM + 1})
    return analyze(
        lambda p: sharded_neighbor_csr(p, 0.05, capacity=_SHARD_CAP,
                                       mesh=mesh, halo_cap=_HALO_CAP,
                                       index_dtype=index_dtype),
        (pts,),
        name=f"sharded_neighbor_csr[{jnp.dtype(index_dtype).name}]",
        scale=SymbolicScale(dims=dims, axes={"data": N_SHARDS}),
        input_ivals=[Ival(0.0, 1.0)], x64=x64)


def _audit_sharded_int64(fast: bool) -> AbsintReport:
    import jax.numpy as jnp
    return _run_sharded(fast, jnp.int64, x64=True)


def _fixture_sharded_int32(fast: bool) -> AbsintReport:
    import jax.numpy as jnp
    return _run_sharded(fast, jnp.int32, x64=False)


def _fixture_min_image_f32(fast: bool) -> AbsintReport:
    """The paper's periodic-boundary fold applied to the BIG=1e15 ghost
    fill in f32: round() of an operand past 2^24 has ulp spacing > 1, so
    ``round(BIG/L)*L == BIG`` and the fold is an identity (ROADMAP item 3
    trap). The analyzer must derive this from the interval, not from a
    pattern."""
    import jax.numpy as jnp

    L = 100.0

    def min_image(dx):
        # the deliberately-broken twin; the analyzer must rediscover R4's
        # trap from intervals alone  # staticcheck: minimage-ok
        return dx - jnp.round(dx / L) * L

    dx = jnp.zeros((N_STAGE,), jnp.float32)
    return analyze(min_image, (dx,), name="min_image_f32",
                   scale=SymbolicScale(dims=scale_for(N_STAGE, N_SYM)),
                   input_ivals=[Ival(-1.0e15, 1.0e15)])


def _fixture_cancellation(fast: bool) -> AbsintReport:
    """Catastrophic cancellation under a precision floor: subtracting
    overlapping ~1e9-magnitude f32 intervals leaves ~128 absolute error —
    fatal when the caller needs 1e-3 (velocity-dispersion style sums)."""
    import jax.numpy as jnp

    a = jnp.zeros((N_STAGE,), jnp.float32)
    return analyze(lambda x, y: x - y, (a, a), name="cancellation_f32",
                   scale=SymbolicScale(dims=scale_for(N_STAGE, N_SYM),
                                       precision_floor=1e-3),
                   input_ivals=[Ival(1.0e9, 1.1e9), Ival(1.0e9, 1.1e9)])


def _fixture_sentinel_gather(fast: bool) -> AbsintReport:
    """A neighbor list whose "no neighbor" sentinel is ``n`` used directly
    as a gather index: jnp stages PROMISE_IN_BOUNDS, and at symbolic N the
    index interval [0, N] is not inside [0, N-1]. The fix — clip or a
    sentinel-aware where — analyzes clean (see tests/test_absint.py)."""
    import jax.numpy as jnp

    labels = jnp.zeros((N_STAGE,), jnp.int32)
    idx = jnp.zeros((N_STAGE,), jnp.int32)
    return analyze(lambda lab, i: lab[i], (labels, idx),
                   name="sentinel_gather",
                   scale=SymbolicScale(dims=scale_for(N_STAGE, N_SYM)),
                   input_ivals=[Ival(0, 100), Ival(0, N_SYM)])


def _fixture_bad_route(fast: bool) -> AbsintReport:
    """A shard_map halo exchange whose ppermute routes two sources onto
    one destination — not a partial permutation; one shard's halo is
    silently dropped."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))

    def exchange(x):
        def body(xs):
            return jax.lax.ppermute(xs, "data", [(0, 0), (0, 0)])
        return shard_map(body, mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"))(x)

    pts = _points()
    return analyze(exchange, (pts,), name="bad_route",
                   scale=SymbolicScale(dims=scale_for(N_STAGE, N_SYM),
                                       axes={"data": N_SHARDS}),
                   input_ivals=[Ival(0.0, 1.0)])


def _audit_wavefront_pallas(fast: bool) -> AbsintReport:
    """The wavefront (Pallas) backend at symbolic N: the padded lane
    bookkeeping around the kernel (arange, pad, slice, the CSR cumsum)
    must prove its index widths like every other path; the pallas_call
    itself is an unknown primitive whose outputs fall back to top —
    soundly silent, never a false positive."""
    from repro.core.query import query_count

    bvh, pred, _ = _csr_args()
    return analyze(
        lambda b, p: query_count(b, p, backend="pallas", sort_queries=True),
        (bvh, pred),
        name="query_count[pallas]",
        scale=SymbolicScale(dims=scale_for(N_STAGE, N_SYM)))


REGISTERED_ABSINT_AUDITS: list[AbsintAudit] = [
    AbsintAudit("query_csr_device/int64", _audit_csr_int64),
    AbsintAudit("query_count/pallas", _audit_wavefront_pallas),
    AbsintAudit("fdbscan", _audit_fdbscan),
    AbsintAudit("fdbscan_pair", _audit_fdbscan_pair),
    AbsintAudit("morton_sort", _audit_morton_sort),
    AbsintAudit("sharded_neighbor_csr/int64", _audit_sharded_int64),
]

# name -> (audit, the one rule that must fire). The int32 configurations
# are real code paths (the pre-PR defaults), not synthetic ASTs: the
# analyzer rediscovers each historical trap from intervals alone.
SEEDED_FIXTURES: list[AbsintAudit] = [
    AbsintAudit("query_csr_device/int32@64e9", _fixture_csr_int32,
                expect_rules=("W1-index-width",)),
    AbsintAudit("sharded_neighbor_csr/int32@64shards", _fixture_sharded_int32,
                expect_rules=("W1-index-width",)),
    AbsintAudit("min_image/f32@BIG", _fixture_min_image_f32,
                expect_rules=("W2-precision",)),
    AbsintAudit("cancellation/f32@floor", _fixture_cancellation,
                expect_rules=("W2-precision",)),
    AbsintAudit("sentinel_gather/unclipped", _fixture_sentinel_gather,
                expect_rules=("W3-bounds",)),
    AbsintAudit("halo_exchange/bad_route", _fixture_bad_route,
                expect_rules=("W3-routes",)),
]


def run_absint_audits(fast: bool = False):
    """Run the registered (clean) audits. Returns ``(findings, reports)``
    where ``findings`` fold into the staticcheck exit code and
    ``reports`` carry the per-entrypoint coverage counters."""
    findings: list[Finding] = []
    reports: list[AbsintReport] = []
    audits = REGISTERED_ABSINT_AUDITS
    if fast:
        # the sharded trace dominates wall time; --fast keeps the rest
        audits = [a for a in audits if not a.name.startswith("sharded")]
    for audit in audits:
        rep = audit.run(fast)
        rep.findings = [f for f in rep.findings
                        if f.rule not in audit.allow]
        reports.append(rep)
        findings.extend(rep.findings)
    return findings, reports


_COVERAGE_CACHE: dict | None = None


def absint_coverage() -> dict:
    """Benchmark-artifact metadata block: one fast registered-audit pass,
    memoized per process. ``seconds: 0.0`` keeps it out of the timing
    gate in ``benchmarks/compare.py`` (records at 0.0 never gate)."""
    global _COVERAGE_CACHE
    if _COVERAGE_CACHE is None:
        findings, reports = run_absint_audits(fast=True)
        _COVERAGE_CACHE = {
            "seconds": 0.0,
            "rules": ["W1-index-width", "W2-precision", "W3-bounds/routes"],
            "entrypoints": [r.name for r in reports],
            "values_analyzed": int(sum(r.values_analyzed for r in reports)),
            "findings": len(findings),
        }
    return dict(_COVERAGE_CACHE)
