"""Scale-safety abstract interpreter over closed jaxprs.

``repro.staticcheck``'s third layer: where the jaxpr audits gate program
STRUCTURE and the AST lint gates source idioms, this layer gates program
VALUES — it walks a traced jaxpr once, propagating an interval per array
(``lattice.Ival``), and asks whether the program still holds together when
the staged toy shapes are re-read as **symbolic exascale sizes** (N=1e9
points, 64 shards) without retracing.

Rule families
-------------

* **W1 index-width** — a *signed* integer op whose output interval escapes
  its dtype at symbolic N (int32 ``counts → cumsum → offsets`` CSR
  overflow, ``shard * n_local + i`` global-id overflow, narrowing
  ``convert_element_type`` truncation). Unsigned arithmetic *wraps*
  (two's-complement), so deliberate wraparound — Morton magic-number
  multiplies — stays silent; a finding fires only at the first eqn whose
  inputs were still representable.
* **W2 precision** — a float quantization (``round`` / ``floor`` /
  ``ceil`` / float→int convert) whose operand magnitude reaches
  2^mantissa (2^24 f32): the ulp spacing exceeds 1 and integer rounding
  is meaningless — the machine-derived form of the ``round(BIG/L)*L ==
  BIG`` min-image trap (ROADMAP item 3). With ``precision_floor`` set, a
  subtraction of overlapping large-magnitude intervals (catastrophic
  cancellation) also fires when the ulp at the operands exceeds the
  floor.
* **W3 bounds & routes** — a gather/scatter staged with
  ``PROMISE_IN_BOUNDS`` whose index interval is not provably inside the
  (symbolic) indexed axis; CLIP / FILL_OR_DROP modes are the sentinel-
  padding idiom and stay silent. Plus the collective-route audit:
  ``ppermute`` route tables must be partial permutations (unique
  sources, unique destinations, ids within the mesh axis) and
  ``psum``/``pmax``/``pmin``/``all_gather`` axis names must name mesh
  axes of the enclosing ``shard_map``.

Symbolic sizes: stage the program at small *marker* sizes (e.g. n=254),
then analyze under ``SymbolicScale(dims={254: 10**9}, axes={"data": 64})``
— every shape and integer literal equal to a marker is re-read at the
symbolic size, so ``iota``/``cumsum``/``reduce_sum``/``axis_index``
bounds reflect the exascale run. ``scale_for(n, N)`` builds the marker
family {n, n±1, 2n-1, 2n-2} for BVH-shaped programs.

Soundness posture: unmodelled primitives and unstable while-loop carries
degrade to ``known=False`` fallbacks that never fire findings — false
negatives are possible, false positives are what the rules are built to
avoid. ``scan`` carries use linear widening (per-iteration drift × trip
count), so accumulator overflow in scans is still caught.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

from repro.staticcheck import lattice as lat
from repro.staticcheck.findings import Finding
from repro.staticcheck.lattice import Ival

__all__ = [
    "SymbolicScale",
    "scale_for",
    "AbsintReport",
    "CollectiveUse",
    "analyze",
    "analyze_jaxpr",
    "audit_routes",
]

_WHILE_JOIN_ITERS = 4


def _fmt(x) -> str:
    """Exact display for integral bounds (an off-by-one W3 finding must
    not print as '[0, 1e+09] outside [0, 1e+09]')."""
    if isinstance(x, int) and abs(x) < 10**15:
        return str(x)
    if isinstance(x, float) and math.isfinite(x) and x.is_integer() \
            and abs(x) < 10**15:
        return str(int(x))
    return f"{x:.4g}"


class SymbolicScale(NamedTuple):
    """The staged-size → symbolic-size re-reading.

    ``dims``: marker dim/literal sizes → symbolic sizes (choose distinctive
    staged markers ≥ 64 so ordinary small constants never collide).
    ``axes``: mesh axis name → symbolic shard count (``axis_index`` /
    ``psum`` bounds). ``precision_floor``: enables the W2 cancellation rule
    at the given absolute-precision requirement (off when None).
    """
    dims: dict = {}
    axes: dict = {}
    precision_floor: float = None

    def dim(self, d: int) -> int:
        return int(self.dims.get(int(d), int(d)))

    def lit(self, v):
        """Re-read an integer literal that equals a marker size."""
        if isinstance(v, (int,)) and not isinstance(v, bool) and v in self.dims:
            return int(self.dims[v])
        return v

    def axis_size(self, name: str, staged: int) -> int:
        return int(self.axes.get(name, staged))


def scale_for(n: int, N: int, extra: dict | None = None) -> dict:
    """Marker family for a BVH-shaped program staged at ``n`` leaves:
    maps n, n±1 and the internal-node counts 2n-1 / 2n-2 to their
    symbolic counterparts. Merge ``extra`` marker→symbolic pairs on top."""
    dims = {n: N, n - 1: N - 1, n + 1: N + 1,
            2 * n - 1: 2 * N - 1, 2 * n - 2: 2 * N - 2}
    dims.update(extra or {})
    return dims


@dataclasses.dataclass
class AbsintReport:
    """One analysis run: findings + coverage counters."""
    name: str
    findings: list
    values_analyzed: int = 0
    eqns_visited: int = 0
    unknown_prims: int = 0
    collectives: list = dataclasses.field(default_factory=list)


class CollectiveUse(NamedTuple):
    """One collective op lifted out of a shard_map region."""
    prim: str              # "ppermute" | "psum" | "pmax" | ...
    axes: tuple            # axis names the op names
    perm: tuple            # ppermute route table ((src, dst), ...) or ()
    mesh_axes: dict        # enclosing mesh: axis name -> staged size


# ---------------------------------------------------------------------------
# The interpreter
# ---------------------------------------------------------------------------

def _aval_dtype(var):
    return getattr(var.aval, "dtype", None)


def _is_literal(v) -> bool:
    return type(v).__name__ == "Literal"


_SHAPE_ONLY = frozenset((
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "transpose",
    "rev", "copy", "stop_gradient", "slice", "device_put",
    "sharding_constraint", "optimization_barrier"))

# Subset safe for guard-refinement aliasing: lane i of the output is lane i
# (or a replica) of the input, so a lanewise predicate on the root still
# describes the aliased value. transpose/rev/slice reorder lanes and must
# not alias.
_LANE_SAFE = frozenset((
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "copy",
    "stop_gradient", "device_put", "sharding_constraint",
    "optimization_barrier"))


def _is_index_use(eqn, var) -> bool:
    """Is ``var`` consumed only at index-operand positions of this eqn?"""
    name = eqn.primitive.name
    if name == "gather" or name.startswith("scatter"):
        idx_pos = (1,)
    elif name == "dynamic_slice":
        idx_pos = tuple(range(1, len(eqn.invars)))
    elif name == "dynamic_update_slice":
        idx_pos = tuple(range(2, len(eqn.invars)))
    else:
        return False
    return (any(eqn.invars[j] is var for j in idx_pos)
            and all(eqn.invars[j] is not var
                    for j in range(len(eqn.invars)) if j not in idx_pos))


class _Interp:
    def __init__(self, scale: SymbolicScale, name: str, rules):
        self.scale = scale
        self.name = name
        self.rules = frozenset(rules)
        self.findings: dict = {}     # dedup key -> Finding
        self.report = AbsintReport(name=name, findings=[])
        self.mesh_stack: list = []   # enclosing shard_map meshes
        # Cross-level guard provenance: jnp.where stages as a pjit whose
        # select_n sits one jaxpr BELOW the comparison producing its
        # predicate, so the same-level producer scan cannot refine it.
        # These maps are keyed by Var object (unique per trace; pjit-cached
        # inner vars are re-bound at each _sub call before use):
        self.guard_of: dict = {}     # cmp outvar -> (op, x_root, const)
        self.lin_of: dict = {}       # add/sub outvar -> (x_root, delta)
        self.alias: dict = {}        # var -> root var (shape-only, bindings)
        self.val_of: dict = {}       # var -> latest Ival (cross-level read)

    def _resolve(self, v):
        while v in self.alias:
            v = self.alias[v]
        return v

    # -- findings ----------------------------------------------------------

    def _emit(self, rule: str, ctx: str, message: str):
        # dedup per (rule, eqn path): loop fixpoint iterations revisit the
        # same eqn with growing intervals — keep the first firing only.
        key = (rule, ctx)
        if key not in self.findings:
            self.findings[key] = Finding(
                rule=rule, path=f"<absint:{self.name}>", line=0,
                message=f"[{ctx}] {message}")

    # -- env helpers -------------------------------------------------------

    def _read(self, env, v) -> Ival:
        if _is_literal(v):
            x = v.val
            try:
                x = x.item()
            except AttributeError:
                pass
            if isinstance(x, bool):
                return lat.const(int(x))
            if isinstance(x, int):
                return lat.const(self.scale.lit(x))
            if isinstance(x, float):
                return lat.const(x)
            return lat.dtype_top(_aval_dtype(v))
        return env.get(v, lat.dtype_top(_aval_dtype(v)))

    def _write(self, env, var, val: Ival):
        dtype = _aval_dtype(var)
        if dtype is not None and lat.is_unsigned_int(dtype):
            val = lat.wrap_unsigned(val, dtype)
        env[var] = val
        self.val_of[var] = val
        self.report.values_analyzed += 1

    def _sym_shape(self, var):
        return tuple(self.scale.dim(d) for d in getattr(var.aval, "shape", ())
                     if isinstance(d, int))

    def _only_deferred_uses(self, var, accept) -> bool:
        """True when every later use of ``var`` in the current jaxpr
        (followed transitively through shape-only eqns) satisfies
        ``accept(eqn, v)`` and never reaches a jaxpr output — the value's
        judgment is deferred to those consuming eqns."""
        eqns = getattr(self, "_cur_eqns", None)
        if eqns is None:
            return False
        outvars = getattr(self, "_cur_outvars", ())
        aliased = {var}
        if any(v in aliased for v in outvars):
            return False
        used = False
        for eqn in eqns[self._cur_idx + 1:]:
            hit = [v for v in eqn.invars if not _is_literal(v) and v in aliased]
            if not hit:
                continue
            if eqn.primitive.name in _SHAPE_ONLY:
                for o in eqn.outvars:
                    if any(o is ov for ov in outvars):
                        return False
                    aliased.add(o)
                continue
            if all(accept(eqn, v) for v in hit):
                used = True
                continue
            return False
        return used

    def _only_select_case_uses(self, var) -> bool:
        """Every later use of ``var`` is as a *case* of a ``select_n``
        (never the predicate, never any other eqn, never an output). Such a
        value is dead on the lanes where it is not selected, so its
        interval is judged after guard refinement at the select instead of
        at the producing eqn."""
        return self._only_deferred_uses(
            var, lambda eqn, v: (eqn.primitive.name == "select_n"
                                 and eqn.invars[0] is not v))

    def _only_gather_index_uses(self, var) -> bool:
        """Every later use of ``var`` is as the index operand of a
        gather/scatter (or a start index of a dynamic slice). jnp
        specializes index dtypes to the STAGED operand size — an int64
        index is narrowed to int32 when the toy array fits, an artifact
        that vanishes at real N. Judgment moves to the consuming eqn: a
        genuinely truncated index still fails the W3 bounds check there."""
        return self._only_deferred_uses(var, _is_index_use)

    # -- W-rule checks -----------------------------------------------------

    def _check_w1(self, eqn, ctx, ins, outs):
        if "W1" not in self.rules:
            return
        # fire only where the overflow FIRST happens: skip if an input
        # already escaped its own dtype (reported upstream).
        for v, iv in ins:
            dt = _aval_dtype(v)
            if dt is None or not iv.known:
                continue
            b = lat.int_bounds(dt)
            if b and lat.is_signed_int(dt) and (iv.lo < b[0] or iv.hi > b[1]):
                return
        # jnp's negative-index canonicalization computes ``i + size``
        # unconditionally and selects it only for i < 0 lanes — a value
        # consumed solely as select_n cases is judged at the select (where
        # guard refinement applies), not here.
        if all(self._only_select_case_uses(var) for var, _ in outs):
            return
        # jnp specializes gather/scatter index dtypes to the STAGED operand
        # size (int64 indices narrowed to int32 when the toy array fits) —
        # defer narrowing converts used only as indices to the consuming
        # eqn's W3 bounds check.
        if (eqn.primitive.name == "convert_element_type"
                and all(self._only_gather_index_uses(var)
                        for var, _ in outs)):
            return
        for var, iv in outs:
            dt = _aval_dtype(var)
            if dt is None or not iv.known or not lat.is_signed_int(dt):
                continue
            b = lat.int_bounds(dt)
            if b and (iv.lo < b[0] or iv.hi > b[1]):
                self._emit(
                    "W1-index-width", ctx,
                    f"{eqn.primitive.name}: {dt} result spans "
                    f"[{_fmt(iv.lo)}, {_fmt(iv.hi)}] at symbolic N — "
                    f"exceeds the dtype range [{_fmt(b[0])}, {_fmt(b[1])}]"
                    f"; widen the "
                    f"index dtype (index_dtype=int64 under x64) or annotate "
                    f"'# staticcheck: width-ok'")

    def _check_w2_quantize(self, eqn, ctx, operand_var, iv):
        if "W2" not in self.rules or not iv.known:
            return
        dt = _aval_dtype(operand_var)
        m = lat.mantissa_bits(dt)
        if m is None:
            return
        mag = iv.maxmag()
        if mag >= float(1 << m):
            self._emit(
                "W2-precision", ctx,
                f"{eqn.primitive.name}: quantizing a {dt} operand with "
                f"magnitude up to {mag:.4g} — ulp spacing "
                f"{lat.ulp_at(mag, dt):.4g} exceeds 1 beyond 2^{m}, so "
                f"integer rounding collapses (the round(BIG/L)*L == BIG "
                f"min-image trap); fold in f64 or clamp the operand first")

    def _check_w2_cancel(self, eqn, ctx, a_var, a, b_var, b, out):
        floor = self.scale.precision_floor
        if "W2" not in self.rules or floor is None:
            return
        dt = _aval_dtype(a_var)
        if not lat.is_float(dt) or not (a.known and b.known):
            return
        if not a.overlaps(b):
            return
        mag = min(a.maxmag(), b.maxmag())
        if mag == 0 or math.isinf(mag):
            return
        if lat.ulp_at(mag, dt) > floor:
            self._emit(
                "W2-precision", ctx,
                f"sub: catastrophic cancellation risk — {dt} operands of "
                f"magnitude ~{mag:.4g} may cancel, leaving absolute error "
                f"~{lat.ulp_at(mag, dt):.4g} > precision_floor={floor:.4g}; "
                f"use a two-pass/compensated formulation")

    def _check_w3_bounds(self, eqn, ctx, idx_iv: Ival, limit: int, kind: str):
        if "W3" not in self.rules or not idx_iv.known:
            return
        if idx_iv.lo < 0 or idx_iv.hi > limit - 1:
            self._emit(
                "W3-bounds", ctx,
                f"{kind}: PROMISE_IN_BOUNDS index interval "
                f"[{_fmt(idx_iv.lo)}, {_fmt(idx_iv.hi)}] is not provably "
                f"inside [0, {_fmt(limit - 1)}] at symbolic N — clip the "
                f"index or use "
                f"mode='clip'/'fill_or_drop' for sentinel padding")

    # -- jaxpr walk --------------------------------------------------------

    def run(self, jaxpr, consts, args, ctx: str, bind=None):
        env: dict = {}
        for var, iv in zip(jaxpr.constvars, consts):
            env[var] = iv
            self.val_of[var] = iv
        for var, iv in zip(jaxpr.invars, args):
            env[var] = iv if iv is not None else lat.dtype_top(_aval_dtype(var))
            self.val_of[var] = env[var]
        if bind is not None:
            # 1:1 call-site binding (pjit): alias inner invars to their
            # outer arguments so guard provenance crosses the jaxpr edge.
            for ivar, ovar in zip(jaxpr.invars, bind):
                if not _is_literal(ovar):
                    self.alias[ivar] = self._resolve(ovar)
        prev = (getattr(self, "_cur_eqns", None), getattr(self, "_cur_idx", 0),
                getattr(self, "_cur_outvars", ()))
        self._cur_outvars = jaxpr.outvars
        for i, eqn in enumerate(jaxpr.eqns):
            self.report.eqns_visited += 1
            # the cursor lets select_n refinement find producer eqns
            self._cur_eqns, self._cur_idx = jaxpr.eqns, i
            _eqn(self, env, eqn, f"{ctx}.{i}" if ctx else str(i))
        self._cur_eqns, self._cur_idx, self._cur_outvars = prev
        return [self._read(env, v) for v in jaxpr.outvars]

    def _sub(self, closed, in_ivals, ctx, bind=None):
        inner = closed.jaxpr if hasattr(closed, "jaxpr") else closed
        consts = [self._read({}, v) if _is_literal(v) else
                  lat.dtype_top(_aval_dtype(v)) for v in inner.constvars]
        if hasattr(closed, "consts"):
            consts = [self._const_ival(c, v) for c, v in
                      zip(closed.consts, inner.constvars)]
        return self.run(inner, consts, in_ivals, ctx, bind=bind)

    def _const_ival(self, c, var) -> Ival:
        try:
            import numpy as np
            arr = np.asarray(c)
            if arr.size == 0:
                return lat.dtype_top(_aval_dtype(var))
            if arr.dtype.kind in "iub":
                return Ival(int(arr.min()), int(arr.max()), True)
            if arr.dtype.kind == "f":
                lo, hi = float(arr.min()), float(arr.max())
                if math.isnan(lo) or math.isnan(hi):
                    return lat.dtype_top(_aval_dtype(var))
                return Ival(lo, hi, True)
        except Exception:
            pass
        return lat.dtype_top(_aval_dtype(var))

    # -- refinement for canonicalized indexing -----------------------------

    def _refine_case(self, env, jaxpr_eqns, case_var, pred_var, guard, i):
        """Interval of ``case_var`` under the constraint ``pred_var`` ∈
        guard. One step of back-substitution: if the case IS the guarded
        var, meet; if it is ``guarded ± literal``, meet then shift. This is
        exactly the shape of jnp's negative-index canonicalization
        ``select_n(i < 0, i, i + n)`` — without it every well-bounded
        ``x[i]`` gather would look out-of-bounds under W3."""
        base = self._read(env, case_var)
        if _is_literal(case_var):
            return base
        if case_var is pred_var:
            m = lat.meet(base, guard)
            return m
        eqn = self._producer(jaxpr_eqns, case_var, i)
        if eqn is not None and eqn.primitive.name in ("add", "sub"):
            a, b = eqn.invars
            for x, off, sign in ((a, b, 1), (b, a, 1)):
                if x is pred_var and _is_literal(off):
                    d = self._read(env, off)
                    if not d.is_point():
                        continue
                    m = lat.meet(self._read(env, x), guard)
                    if m is None:
                        return None
                    shift = d.lo if eqn.primitive.name == "add" else -d.lo
                    if eqn.primitive.name == "sub" and x is b:
                        continue
                    return Ival(m.lo + shift, m.hi + shift, m.known)
        return base

    def _refine_case_global(self, env, case_var, x_root, xval, guard):
        """Cross-level variant of ``_refine_case``: the guarded var is
        identified by its alias ROOT rather than a same-level producer
        scan, so ``jnp.where(x < c, x, y)`` refines even when the select
        sits inside a pjit and the cmp in its parent."""
        base = self._read(env, case_var)
        if _is_literal(case_var):
            return base
        root = self._resolve(case_var)
        if root is x_root:
            m = lat.meet(base, guard)
            return base if m is None else m
        lin = self.lin_of.get(root)
        if lin is not None and lin[0] is x_root:
            m = lat.meet(xval, guard)
            if m is not None:
                return Ival(m.lo + lin[1], m.hi + lin[1], m.known)
        return base

    @staticmethod
    def _producer(eqns, var, before):
        for eqn in eqns[:before][::-1]:
            if any(o is var for o in eqn.outvars):
                return eqn
        return None


# The per-eqn transfer dispatch lives outside the class body for length.

def _eqn(self: _Interp, env, eqn, ctx):
    prim = eqn.primitive.name
    scale = self.scale
    read = lambda v: self._read(env, v)
    ins = [read(v) for v in eqn.invars]

    def out(val: Ival, check_w1=True):
        for var in eqn.outvars:
            self._write(env, var, val)
        if check_w1:
            self._check_w1(eqn, ctx,
                           list(zip(eqn.invars, ins)),
                           [(v, val) for v in eqn.outvars])

    def fallback():
        self.report.unknown_prims += 1
        for var in eqn.outvars:
            self._write(env, var, lat.dtype_top(_aval_dtype(var)))

    # --- structured control flow ----------------------------------------
    if prim in ("pjit", "closed_call", "core_call", "xla_call", "remat_call",
                "remat", "checkpoint", "custom_jvp_call", "custom_vjp_call",
                "custom_vjp_call_jaxpr"):
        closed = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                  or eqn.params.get("fun_jaxpr"))
        if closed is None:
            return fallback()
        label = eqn.params.get("name", prim)
        outs = self._sub(closed, ins, f"{ctx}/{label}", bind=eqn.invars)
        for var, val in zip(eqn.outvars, outs):
            self._write(env, var, val)
        return

    if prim == "cond":
        branches = eqn.params["branches"]
        opers = ins[1:]
        branch_outs = [self._sub(br, opers, f"{ctx}/cond{k}")
                       for k, br in enumerate(branches)]
        for j, var in enumerate(eqn.outvars):
            val = branch_outs[0][j]
            for bo in branch_outs[1:]:
                val = lat.join(val, bo[j])
            self._write(env, var, val)
        return

    if prim == "while":
        return _while(self, env, eqn, ctx, ins)

    if prim == "scan":
        return _scan(self, env, eqn, ctx, ins)

    if prim == "shard_map":
        return _shard_map(self, env, eqn, ctx, ins)

    if prim == "pallas_call":
        # Pallas kernel bodies operate on Refs through load/store effects —
        # outside this value lattice (the jaxpr-audit walker in
        # staticcheck.audits does descend into them). Model the launch
        # soundly instead: every output covers its full dtype range, so
        # downstream W1 reasoning stays honest without claiming knowledge
        # of in-kernel values.
        for var in eqn.outvars:
            self._write(env, var, lat.dtype_top(_aval_dtype(var)))
        return

    # --- collectives ------------------------------------------------------
    if prim == "ppermute":
        axes = _axis_names(eqn)
        perm = tuple(tuple(p) for p in eqn.params.get("perm", ()))
        _record_collective(self, prim, axes, perm)
        # devices with no sender receive zeros
        return out(lat.join(ins[0], lat.const(0)))
    if prim in ("psum", "psum2", "psum_invariant"):
        axes = _axis_names(eqn)
        _record_collective(self, prim, axes, ())
        count = 1
        for a in axes:
            staged = self._mesh_size(a)
            count *= scale.axis_size(a, staged)
        return out(lat.scale_by_count(ins[0], count))
    if prim in ("pmax", "pmin", "all_gather", "pbroadcast", "all_to_all"):
        _record_collective(self, prim, _axis_names(eqn), ())
        return out(ins[0])
    if prim == "axis_index":
        a = eqn.params.get("axis_name")
        staged = self._mesh_size(a)
        return out(Ival(0, scale.axis_size(a, staged) - 1, True))

    # --- element-wise arithmetic -----------------------------------------
    if prim == "add":
        _note_lin(self, eqn, ins, 1)
        return out(lat.add(ins[0], ins[1]))
    if prim == "sub":
        self._check_w2_cancel(eqn, ctx, eqn.invars[0], ins[0],
                              eqn.invars[1], ins[1], None)
        _note_lin(self, eqn, ins, -1)
        return out(lat.sub(ins[0], ins[1]))
    if prim == "mul":
        return out(lat.mul(ins[0], ins[1]))
    if prim == "div":
        val = lat.div(ins[0], ins[1])
        dt = _aval_dtype(eqn.outvars[0])
        if lat.is_signed_int(dt) or lat.is_unsigned_int(dt):
            val = lat.truncate(val)  # lax.div truncates toward zero on ints
        return out(val)
    if prim == "rem":
        return out(lat.rem(ins[0], ins[1]))
    if prim == "neg":
        return out(lat.neg(ins[0]))
    if prim == "abs":
        return out(lat.iabs(ins[0]))
    if prim == "sign":
        return out(Ival(-1, 1, ins[0].known))
    if prim in ("min", "minimum"):
        return out(lat.imin(ins[0], ins[1]))
    if prim in ("max", "maximum"):
        return out(lat.imax(ins[0], ins[1]))
    if prim == "clamp":
        lo, x, hi = ins
        return out(lat.imax(lo, lat.imin(x, hi)))
    if prim == "square":
        return out(lat.mul(ins[0], ins[0]))
    if prim == "integer_pow":
        return _int_pow(out, ins[0], eqn.params.get("y", 1))
    if prim == "pow":
        return fallback()
    if prim == "sqrt":
        a = ins[0]
        return out(Ival(math.sqrt(max(a.lo, 0.0)),
                        math.sqrt(max(a.hi, 0.0)) if not math.isinf(a.hi)
                        else math.inf, a.known))
    if prim == "exp":
        return out(lat.monotonic(ins[0], lambda x: math.exp(min(x, 700.0))))
    if prim == "log":
        a = ins[0]
        return out(Ival(-math.inf if a.lo <= 0 else math.log(a.lo),
                        -math.inf if a.hi <= 0 else
                        (math.inf if math.isinf(a.hi) else math.log(a.hi)),
                        a.known))
    if prim in ("tanh", "erf", "sin", "cos"):
        return out(Ival(-1.0, 1.0, ins[0].known))
    if prim == "logistic":
        return out(Ival(0.0, 1.0, ins[0].known))
    if prim == "is_finite":
        return out(Ival(0, 1, True))
    if prim in ("floor", "ceil", "round", "nearbyint", "round_nearest_even"):
        self._check_w2_quantize(eqn, ctx, eqn.invars[0], ins[0])
        f = {"floor": lat.floor_op, "ceil": lat.ceil_op}.get(prim,
                                                             lat.round_op)
        return out(f(ins[0]))
    if prim == "convert_element_type":
        return _convert(self, env, eqn, ctx, ins, out)

    # --- bitwise ----------------------------------------------------------
    if prim == "and":
        dt = _aval_dtype(eqn.outvars[0])
        if getattr(dt, "name", str(dt)) == "bool":
            return out(Ival(0, 1, ins[0].known and ins[1].known))
        return out(lat.bit_and(ins[0], ins[1]))
    if prim == "or":
        dt = _aval_dtype(eqn.outvars[0])
        if getattr(dt, "name", str(dt)) == "bool":
            return out(Ival(0, 1, ins[0].known and ins[1].known))
        return out(lat.bit_or(ins[0], ins[1]))
    if prim == "xor":
        dt = _aval_dtype(eqn.outvars[0])
        if getattr(dt, "name", str(dt)) == "bool":
            return out(Ival(0, 1, ins[0].known and ins[1].known))
        return out(lat.bit_xor(ins[0], ins[1]))
    if prim == "not":
        return out(Ival(0, 1, ins[0].known))
    if prim == "shift_left":
        return out(lat.shift_left(ins[0], ins[1]))
    if prim == "shift_right_logical":
        return out(lat.shift_right(ins[0], ins[1], arithmetic=False))
    if prim == "shift_right_arithmetic":
        return out(lat.shift_right(ins[0], ins[1], arithmetic=True))
    if prim in ("clz", "population_count"):
        return out(Ival(0, 64, True))

    # --- comparisons ------------------------------------------------------
    if prim in ("eq", "ne", "lt", "le", "gt", "ge"):
        if prim in ("lt", "le", "gt", "ge"):
            a, b = eqn.invars
            av, bv = ins
            swap = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le"}
            if bv.is_point() and not _is_literal(a):
                self.guard_of[eqn.outvars[0]] = (prim, self._resolve(a),
                                                 bv.lo)
            elif av.is_point() and not _is_literal(b):
                self.guard_of[eqn.outvars[0]] = (swap[prim],
                                                 self._resolve(b), av.lo)
        return out(Ival(0, 1, True), check_w1=False)

    # --- shape/layout (interval-preserving) ------------------------------
    if prim in _SHAPE_ONLY or prim in ("reduce_precision", "real"):
        if prim in _LANE_SAFE and not _is_literal(eqn.invars[0]):
            self.alias[eqn.outvars[0]] = self._resolve(eqn.invars[0])
        return out(ins[0], check_w1=False)
    if prim == "concatenate":
        val = ins[0]
        for x in ins[1:]:
            val = lat.join(val, x)
        return out(val, check_w1=False)
    if prim == "pad":
        return out(lat.join(ins[0], ins[1]), check_w1=False)
    if prim == "select_n":
        return _select_n(self, env, eqn, ctx, ins, out)

    # --- index generation / reductions -----------------------------------
    if prim == "iota":
        dim = eqn.params.get("dimension", 0)
        shape = getattr(eqn.outvars[0].aval, "shape", (1,))
        n = scale.dim(shape[dim]) if shape else 1
        return out(Ival(0, max(n - 1, 0), True))
    if prim in ("reduce_sum", "cumsum"):
        count = _reduced_count(self, eqn, prim)
        return out(lat.scale_by_count(ins[0], count))
    if prim in ("reduce_max", "reduce_min", "cummax", "cummin"):
        return out(ins[0], check_w1=False)
    if prim in ("reduce_and", "reduce_or"):
        return out(Ival(0, 1, ins[0].known), check_w1=False)
    if prim in ("argmax", "argmin"):
        axes = eqn.params.get("axes", (0,))
        shape = getattr(eqn.invars[0].aval, "shape", (1,))
        n = max((scale.dim(shape[a]) for a in axes), default=1)
        return out(Ival(0, max(n - 1, 0), True))
    if prim == "reduce_prod":
        return fallback()
    if prim == "sort":
        # sort permutes values within each operand independently of keys
        for var, val in zip(eqn.outvars, ins):
            self._write(env, var, val)
        return
    if prim == "dot_general":
        dims = eqn.params.get("dimension_numbers")
        k = 1
        if dims:
            (lc, _), _ = dims
            shape = getattr(eqn.invars[0].aval, "shape", ())
            for a in lc:
                if a < len(shape):
                    k *= scale.dim(shape[a])
        prod = lat.mul(ins[0], ins[1])
        return out(lat.scale_by_count(prod, k))

    # --- gather / scatter -------------------------------------------------
    if prim == "gather":
        return _gather(self, env, eqn, ctx, ins, out)
    if prim.startswith("scatter"):
        return _scatter(self, env, eqn, ctx, ins, out)
    if prim == "dynamic_slice":
        return out(ins[0], check_w1=False)  # start indices are clamped
    if prim == "dynamic_update_slice":
        return out(lat.join(ins[0], ins[1]), check_w1=False)

    return fallback()


def _int_pow(out, a: Ival, y: int):
    y = int(y)
    if y < 0:
        return out(Ival(-math.inf, math.inf, a.known))
    if y == 0:
        return out(Ival(1, 1, a.known))

    def p(x):
        if math.isinf(x):
            return math.inf if (y % 2 == 0 or x > 0) else -math.inf
        try:
            return x ** y
        except OverflowError:
            return math.inf if (y % 2 == 0 or x > 0) else -math.inf

    cs = [p(a.lo), p(a.hi)]
    if y % 2 == 0 and a.lo < 0 < a.hi:
        cs.append(0)
    return out(Ival(min(cs), max(cs), a.known))


def _convert(self: _Interp, env, eqn, ctx, ins, out):
    src = ins[0]
    src_dt = _aval_dtype(eqn.invars[0])
    dst_dt = _aval_dtype(eqn.outvars[0])
    val = src
    if lat.is_float(src_dt) and (lat.is_signed_int(dst_dt)
                                 or lat.is_unsigned_int(dst_dt)):
        self._check_w2_quantize(eqn, ctx, eqn.invars[0], src)
        val = lat.truncate(src)
    if getattr(dst_dt, "name", str(dst_dt)) == "bool":
        val = Ival(0, 1, src.known)
    return out(val)


def _note_lin(self: _Interp, eqn, ins, sign):
    """Record ``out = x ± point`` linear provenance for guard-refinement
    back-substitution across jaxpr levels."""
    a, b = eqn.invars
    av, bv = ins
    if bv.is_point() and not _is_literal(a) and not math.isinf(bv.lo):
        self.lin_of[eqn.outvars[0]] = (self._resolve(a), sign * bv.lo)
    elif sign > 0 and av.is_point() and not _is_literal(b) \
            and not math.isinf(av.lo):
        self.lin_of[eqn.outvars[0]] = (self._resolve(b), av.lo)


def _select_n(self: _Interp, env, eqn, ctx, ins, out):
    pred_var = eqn.invars[0]
    cases = eqn.invars[1:]
    # Path-sensitive refinement when the predicate is a comparison of a
    # var against a point interval (jnp's negative-index canonicalization).
    jaxpr_eqns = getattr(self, "_cur_eqns", [])
    i = getattr(self, "_cur_idx", 0)
    pred_eqn = _Interp._producer(jaxpr_eqns, pred_var, i)
    if pred_eqn is None and len(cases) == 2 and not _is_literal(pred_var):
        # The jnp.where pjit shape: the select's predicate is a jaxpr invar
        # whose producing comparison sits in the PARENT jaxpr. Guard
        # provenance recorded at the cmp crosses the call edge via aliases.
        info = self.guard_of.get(self._resolve(pred_var))
        if info is not None:
            op, x_root, c = info
            xval = self.val_of.get(x_root)
            if xval is not None and xval.known:
                false_g, true_g = _guards(op, c)
                vals = []
                for case_var, guard in ((cases[0], false_g),
                                        (cases[1], true_g)):
                    if lat.meet(xval, guard) is None:
                        continue  # infeasible branch
                    vals.append(self._refine_case_global(
                        env, case_var, x_root, xval, guard))
                if vals:
                    v = vals[0]
                    for w in vals[1:]:
                        v = lat.join(v, w)
                    return out(v, check_w1=False)
    if (pred_eqn is not None and pred_eqn.primitive.name in
            ("lt", "le", "gt", "ge") and len(cases) == 2):
        x_var, c_var = pred_eqn.invars
        cval = self._read(env, c_var)
        xval = self._read(env, x_var)
        if cval.is_point() and not _is_literal(x_var):
            c = cval.lo
            op = pred_eqn.primitive.name
            false_g, true_g = _guards(op, c)
            vals = []
            for case_var, guard in ((cases[0], false_g), (cases[1], true_g)):
                g = lat.meet(xval, guard)
                if g is None:
                    continue  # infeasible branch
                r = self._refine_case(env, jaxpr_eqns, case_var, x_var,
                                      guard, i)
                if r is not None:
                    vals.append(r)
            if vals:
                v = vals[0]
                for w in vals[1:]:
                    v = lat.join(v, w)
                return out(v, check_w1=False)
    val = ins[1]
    for x in ins[2:]:
        val = lat.join(val, x)
    return out(val, check_w1=False)


def _guards(op: str, c):
    """(guard when pred False, guard when pred True) for ``x <op> c``."""
    inf = math.inf
    if op == "lt":
        return Ival(c, inf), Ival(-inf, c - 1 if isinstance(c, int) else c)
    if op == "le":
        return Ival(c + 1 if isinstance(c, int) else c, inf), Ival(-inf, c)
    if op == "gt":
        return Ival(-inf, c), Ival(c + 1 if isinstance(c, int) else c, inf)
    return Ival(-inf, c - 1 if isinstance(c, int) else c), Ival(c, inf)


def _mode_promises(eqn) -> bool:
    mode = eqn.params.get("mode")
    return "PROMISE_IN_BOUNDS" in str(mode)


def _gather(self: _Interp, env, eqn, ctx, ins, out):
    operand, idx = ins[0], ins[1]
    if _mode_promises(eqn):
        dn = eqn.params.get("dimension_numbers")
        shape = getattr(eqn.invars[0].aval, "shape", ())
        dims = getattr(dn, "start_index_map", (0,))
        limit = max((self.scale.dim(shape[d]) for d in dims
                     if d < len(shape)), default=1)
        self._check_w3_bounds(eqn, ctx, idx, limit, "gather")
    return out(operand, check_w1=False)


def _scatter(self: _Interp, env, eqn, ctx, ins, out):
    operand, idx, updates = ins[0], ins[1], ins[2] if len(ins) > 2 else ins[0]
    prim = eqn.primitive.name
    if _mode_promises(eqn):
        dn = eqn.params.get("dimension_numbers")
        shape = getattr(eqn.invars[0].aval, "shape", ())
        dims = getattr(dn, "scatter_dims_to_operand_dims", (0,))
        limit = max((self.scale.dim(shape[d]) for d in dims
                     if d < len(shape)), default=1)
        self._check_w3_bounds(eqn, ctx, idx, limit, prim)
    if prim in ("scatter-add", "scatter_add"):
        upd_shape = getattr(eqn.invars[2].aval, "shape", (1,)) \
            if len(eqn.invars) > 2 else (1,)
        n_upd = 1
        for d in upd_shape:
            n_upd *= self.scale.dim(d)
        # all updates may collapse onto one slot (segment-sum idiom)
        acc = lat.add(operand, lat.scale_by_count(updates, n_upd))
        return out(acc)
    if prim in ("scatter-min", "scatter_min"):
        # scatter-min only LOWERS slots: result ∈ [min(lo), operand.hi].
        # Keeping the operand's hi is what lets sentinel-valued updates
        # (union-find's ``where(core, m, n)``) min into ``parent`` without
        # parent's interval absorbing the out-of-range sentinel.
        return out(Ival(min(operand.lo, updates.lo), operand.hi,
                        operand.known and updates.known), check_w1=False)
    if prim in ("scatter-max", "scatter_max"):
        return out(Ival(operand.lo, max(operand.hi, updates.hi),
                        operand.known and updates.known), check_w1=False)
    return out(lat.join(operand, updates), check_w1=False)


def _reduced_count(self: _Interp, eqn, prim) -> int:
    shape = getattr(eqn.invars[0].aval, "shape", (1,))
    if prim == "reduce_sum":
        axes = eqn.params.get("axes", tuple(range(len(shape))))
    else:  # cumsum: the scanned axis
        axes = (eqn.params.get("axis", 0),)
    count = 1
    for a in axes:
        if a < len(shape):
            count *= self.scale.dim(shape[a])
    return max(count, 1)


def _axis_names(eqn):
    for key in ("axes", "axis_name", "axis_index_groups"):
        v = eqn.params.get(key)
        if key == "axes" and v:
            return tuple(a for a in v if isinstance(a, str)) or tuple(v)
        if key == "axis_name" and v is not None:
            return v if isinstance(v, tuple) else (v,)
    return ()


def _record_collective(self: _Interp, prim, axes, perm):
    mesh_axes = dict(self.mesh_stack[-1]) if self.mesh_stack else {}
    self.report.collectives.append(CollectiveUse(
        prim=prim, axes=tuple(a for a in axes if a is not None),
        perm=perm, mesh_axes=mesh_axes))


def _while(self: _Interp, env, eqn, ctx, ins):
    p = eqn.params
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    body = p["body_jaxpr"]
    cond = p["cond_jaxpr"]
    cond_consts = ins[:cn]
    body_consts = ins[cn:cn + bn]
    carry = list(ins[cn + bn:])
    for it in range(_WHILE_JOIN_ITERS):
        outs = self._sub(body, body_consts + carry, f"{ctx}/while")
        new = [lat.join(c, o) for c, o in zip(carry, outs)]
        if all(n == c for n, c in zip(new, carry)):
            break
        carry = new
    else:
        # unstable components degrade to unknown (no trip count to bound)
        stable = []
        outs = self._sub(body, body_consts + carry, f"{ctx}/while-w")
        for c, o in zip(carry, outs):
            stable.append(c if lat.join(c, o) == c else
                          lat.dtype_top(None))
        carry = stable
        self._sub(body, body_consts + carry, f"{ctx}/while-f")
    self._sub(cond, cond_consts + carry, f"{ctx}/while-c")
    for var, val in zip(eqn.outvars, carry):
        self._write(env, var, val)


def _scan(self: _Interp, env, eqn, ctx, ins):
    p = eqn.params
    nc, ncar = p["num_consts"], p["num_carry"]
    length = self.scale.lit(int(p.get("length", 1)))
    body = p["jaxpr"]
    consts = ins[:nc]
    carry = list(ins[nc:nc + ncar])
    xs = ins[nc + ncar:]
    ys_acc = None
    for it in range(_WHILE_JOIN_ITERS):
        outs = self._sub(body, consts + carry + xs, f"{ctx}/scan")
        new_carry = [lat.join(c, o) for c, o in zip(carry, outs[:ncar])]
        ys = outs[ncar:]
        ys_acc = ys if ys_acc is None else \
            [lat.join(a, y) for a, y in zip(ys_acc, ys)]
        if all(n == c for n, c in zip(new_carry, carry)):
            break
        carry = new_carry
    else:
        # linear widening: extrapolate the per-iteration drift over the
        # (symbolic) trip count — catches scan-accumulator overflow that
        # plain join-until-stable widening would lose.
        outs = self._sub(body, consts + carry + xs, f"{ctx}/scan-w")
        widened = []
        for c, o in zip(carry, outs[:ncar]):
            d_lo = o.lo - c.lo
            d_hi = o.hi - c.hi
            if (c.known and o.known and not math.isinf(d_lo)
                    and not math.isinf(d_hi)):
                widened.append(Ival(c.lo + min(d_lo, 0) * length,
                                    c.hi + max(d_hi, 0) * length, True))
            else:
                widened.append(lat.dtype_top(None))
        carry = widened
        outs = self._sub(body, consts + carry + xs, f"{ctx}/scan-f")
        ys_acc = [lat.join(a, y) for a, y in zip(ys_acc, outs[ncar:])]
    for var, val in zip(eqn.outvars, carry + (ys_acc or [])):
        self._write(env, var, val)
    # W1 on widened scan carries (the accumulator overflow check)
    self._check_w1(eqn, ctx, list(zip(eqn.invars[:nc + ncar],
                                      ins[:nc + ncar])),
                   list(zip(eqn.outvars[:ncar], carry)))


def _shard_map(self: _Interp, env, eqn, ctx, ins):
    p = eqn.params
    mesh = p.get("mesh")
    axes = {}
    if mesh is not None:
        names = getattr(mesh, "axis_names", ())
        try:
            sizes = dict(getattr(mesh, "shape", {}))
        except Exception:
            sizes = {}
        axes = {n: int(sizes.get(n, 1)) for n in names}
    self.mesh_stack.append(axes)
    try:
        inner = p.get("jaxpr")
        outs = self._sub(inner, ins, f"{ctx}/shard_map")
    finally:
        self.mesh_stack.pop()
    for var, val in zip(eqn.outvars, outs):
        self._write(env, var, val)


def _mesh_size(self: _Interp, axis_name) -> int:
    for frame in self.mesh_stack[::-1]:
        if axis_name in frame:
            return frame[axis_name]
    return 1


_Interp._mesh_size = _mesh_size


# ---------------------------------------------------------------------------
# Route audit (W3): permutation bijectivity + axis-name validity
# ---------------------------------------------------------------------------

def audit_routes(uses, name: str) -> list:
    """Check lifted collectives: ``ppermute`` tables must be partial
    permutations of the staged mesh axis (unique sources, unique
    destinations, ids in range); every named axis must be a mesh axis of
    the enclosing ``shard_map``. Returns W3 findings."""
    findings = []

    def emit(msg):
        findings.append(Finding(rule="W3-routes", path=f"<absint:{name}>",
                                line=0, message=msg))

    for use in uses:
        for a in use.axes:
            if use.mesh_axes and a not in use.mesh_axes:
                emit(f"{use.prim}: axis {a!r} is not an axis of the "
                     f"enclosing mesh {sorted(use.mesh_axes)}")
        if use.prim != "ppermute" or not use.perm:
            continue
        size = None
        if use.axes and use.mesh_axes:
            size = use.mesh_axes.get(use.axes[0])
        srcs = [s for s, _ in use.perm]
        dsts = [d for _, d in use.perm]
        if len(set(srcs)) != len(srcs):
            emit(f"ppermute: duplicate source in route table {use.perm} — "
                 f"not a partial permutation")
        if len(set(dsts)) != len(dsts):
            emit(f"ppermute: duplicate destination in route table "
                 f"{use.perm} — two shards would collide")
        if size is not None:
            bad = [x for x in srcs + dsts if not (0 <= x < size)]
            if bad:
                emit(f"ppermute: shard ids {sorted(set(bad))} outside the "
                     f"mesh axis {use.axes[0]!r} of size {size}")
    return findings


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def analyze_jaxpr(closed_jaxpr, *, name: str, scale: SymbolicScale,
                  input_ivals=None, rules=("W1", "W2", "W3")) -> AbsintReport:
    """Analyze a ClosedJaxpr under the symbolic scale. ``input_ivals``: one
    ``Ival`` (or None = unknown) per flat jaxpr input."""
    interp = _Interp(scale, name, rules)
    inner = closed_jaxpr.jaxpr
    consts = [interp._const_ival(c, v)
              for c, v in zip(closed_jaxpr.consts, inner.constvars)]
    n_in = len(inner.invars)
    args = list(input_ivals or [])[:n_in]
    args += [None] * (n_in - len(args))
    interp.run(inner, consts, args, "")
    findings = list(interp.findings.values())
    if "W3" in rules:
        findings += audit_routes(interp.report.collectives, name)
    interp.report.findings = findings
    return interp.report


def _flat_ivals(args, specs):
    """Per-argument interval specs → the jaxpr's flat input order. Each
    spec is None (every leaf unknown), one ``Ival`` (broadcast over the
    argument's leaves), or a structure-matching pytree of Ival/None."""
    import jax
    flat = []
    for a, s in zip(args, specs):
        n_leaves = len(jax.tree.leaves(a))
        if s is None or isinstance(s, Ival):
            flat += [s] * n_leaves
        else:
            leaves = jax.tree.leaves(
                s, is_leaf=lambda x: x is None or isinstance(x, Ival))
            assert len(leaves) == n_leaves, (len(leaves), n_leaves)
            flat += leaves
    return flat


def analyze(fn: Callable, args, *, name: str, scale: SymbolicScale,
            input_ivals=None, rules=("W1", "W2", "W3"),
            x64: bool = False) -> AbsintReport:
    """Trace ``fn(*args)`` (under x64 when asked — the widened-index
    configurations stage int64 programs) and analyze the closed jaxpr.
    ``input_ivals``: one spec per positional argument (see
    ``_flat_ivals``)."""
    import jax

    def trace():
        return jax.make_jaxpr(fn)(*args)

    if x64:
        from jax.experimental import enable_x64
        with enable_x64():
            closed = trace()
    else:
        closed = trace()
    flat = _flat_ivals(args, input_ivals) if input_ivals is not None else None
    return analyze_jaxpr(closed, name=name, scale=scale,
                         input_ivals=flat, rules=rules)
