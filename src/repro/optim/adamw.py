"""AdamW with cosine schedule, global-norm clipping, optional low-precision
moments, gradient accumulation, and int8 gradient compression with error
feedback (the distributed-optimization tricks, DESIGN.md §6).

Optimizer state shards exactly like the parameters (ZeRO: m/v inherit the
param PartitionSpec), so no extra sharding rules are needed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "bfloat16"    # m/v dtype (memory at 398B scale)
    grad_dtype: str = "float32"       # accumulation dtype (bf16 at 398B scale)
    accum_steps: int = 1
    compress_grads: bool = False      # int8 + error feedback (for cross-pod DP)


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    error: Any | None = None          # compression error-feedback buffers


def _mdt(cfg: OptConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def init_opt_state(cfg: OptConfig, params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, _mdt(cfg))
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if cfg.compress_grads else None
    return OptState(step=jnp.int32(0),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params),
                    error=err)


def abstract_opt_state(cfg: OptConfig, abstract_params) -> OptState:
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, _mdt(cfg))
    err = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                       abstract_params) if cfg.compress_grads else None
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    m=jax.tree.map(zeros, abstract_params),
                    v=jax.tree.map(zeros, abstract_params),
                    error=err)


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step.astype(jnp.float32) - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, clip: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# --- int8 gradient compression with error feedback --------------------------

def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, error):
    """Quantize (grad + carried error); new error = residual. The quantized
    grads are what cross-pod data-parallel all-reduces would ship (int8 = 4x
    less DP traffic); decompressed values feed the optimizer."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = compress_int8(target)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), target - deq

    flat = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err


def apply_updates(cfg: OptConfig, params, grads, opt: OptState):
    """One AdamW step. Returns (new_params, new_opt, metrics)."""
    if cfg.compress_grads and opt.error is not None:
        grads, new_error = compress_with_feedback(grads, opt.error)
    else:
        new_error = opt.error

    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt.m, opt.v)
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3 and not hasattr(x, "_fields")
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    new_opt = OptState(step=step, m=new_m, v=new_v, error=new_error)
    return new_params, new_opt, {"grad_norm": gnorm, "lr": lr}
