"""Sharded checkpointing: npz shards + msgpack-free JSON manifest, atomic
commit, async save thread, elastic restore (re-shard to a different mesh).

Layout:
  <dir>/step_<N>/
    manifest.json          # step, leaf paths, shapes, dtypes, shard counts
    shard_<k>.npz          # leaf arrays (flat key -> array), host k's slice
    COMMIT                 # written LAST: a checkpoint without it is torn

Fault-tolerance contract (runtime/supervisor.py):
  * saves are atomic (tmp dir + rename + COMMIT marker),
  * latest_step() ignores uncommitted/torn checkpoints,
  * restore() works onto ANY mesh: arrays are saved unsharded per leaf
    (single-host container) or as host shards that concat on axis 0; the
    caller re-applies shardings, so restoring 256-chip state onto a
    512-chip mesh (elastic reshape) is just a different re-shard.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import tempfile
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = leaf
    return flat


def _unflatten_like(template, flat: dict[str, Any]):
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(template)[0]]
    leaves = [flat[p] for p in paths]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


class CheckpointStore:
    def __init__(self, directory: str | os.PathLike):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._async_thread: threading.Thread | None = None

    # --- save ---------------------------------------------------------------

    def save(self, step: int, tree) -> pathlib.Path:
        """Synchronous atomic save."""
        host_arrays = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host_arrays)

    def save_async(self, step: int, tree) -> None:
        """Device->host copy happens NOW (so training can step on), the disk
        write happens on a background thread (off the step path)."""
        self.wait()
        host_arrays = jax.tree.map(lambda x: np.asarray(x), tree)
        self._async_thread = threading.Thread(
            target=self._write, args=(step, host_arrays), daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, step: int, host_tree) -> pathlib.Path:
        flat = _flatten(host_tree)
        final = self.dir / f"step_{step:08d}"
        tmp = pathlib.Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
        try:
            np.savez(tmp / "shard_0.npz", **{k: np.asarray(v) for k, v in flat.items()})
            manifest = {
                "step": step,
                "time": time.time(),
                "leaves": {k: {"shape": list(np.shape(v)),
                               "dtype": str(np.asarray(v).dtype)}
                           for k, v in flat.items()},
                "num_shards": 1,
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
            (tmp / "COMMIT").write_text("ok")
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        return final

    # --- restore --------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: int | None = None, shardings=None):
        """Restore into the structure of ``template``. ``shardings`` (same
        tree shape, NamedShardings) re-shards onto the CURRENT mesh —
        elastic reshape is just restoring with different shardings."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        if not (path / "COMMIT").exists():
            raise FileNotFoundError(f"checkpoint {path} is torn (no COMMIT)")
        data = np.load(path / "shard_0.npz")
        flat = {k: data[k] for k in data.files}
        tree = _unflatten_like(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(jax.numpy.asarray(x), s), tree, shardings)
        return tree, step

    def prune(self, keep: int = 3) -> None:
        for s in self.steps()[:-keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
