"""In-situ analysis: the paper's contribution embedded in the training loop.

HACC pattern (paper §2): the simulation timesteps on the accelerators and,
every K long-range-force steps, runs FOF/DBSCAN halo finding in-situ —
ArborX made that step ~10x faster so analysis now runs at full cadence.

Our framework's analog: every ``cadence`` optimizer steps, run DBSCAN on
accelerator-resident point clouds derived from training state, without
leaving the device:

* embedding-space clustering — sampled token-embedding rows; detects
  representation collapse / near-duplicate embeddings (minPts=2 ≡ FOF);
* MoE router clustering — expert centroids in router space; detects expert
  collapse (experts whose router columns cluster within ε).

Both consume the SAME clustering core benchmarked in benchmarks/fig4.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dbscan import fdbscan
from repro.core import union_find


@dataclasses.dataclass(frozen=True)
class InsituConfig:
    cadence: int = 10              # analysis every K steps (HACC: ~100/625)
    sample_rows: int = 512         # embedding rows sampled per analysis
    eps_quantile: float = 0.01     # ε from the pairwise-distance quantile
    min_pts: int = 2               # FOF
    project_dim: int = 3           # random projection for the geometric core


def _sample_rows(key, table: jax.Array, n: int) -> jax.Array:
    idx = jax.random.choice(key, table.shape[0], (min(n, table.shape[0]),),
                            replace=False)
    return table[idx]


def _project(key, x: jax.Array, d: int) -> jax.Array:
    """Random projection to the low-dim space the geometric core indexes
    (Johnson-Lindenstrauss: cluster structure survives)."""
    r = jax.random.normal(key, (x.shape[-1], d), jnp.float32) / np.sqrt(x.shape[-1])
    y = x.astype(jnp.float32) @ r
    lo = y.min(axis=0)
    span = jnp.maximum(y.max(axis=0) - lo, 1e-6)
    return (y - lo) / span


def _eps_from_quantile(pts: jax.Array, q: float) -> jax.Array:
    d2 = jnp.sum((pts[:, None] - pts[None]) ** 2, axis=-1)
    n = pts.shape[0]
    off = d2[jnp.triu_indices(n, 1)]
    return jnp.sqrt(jnp.quantile(off, q))


def embedding_cluster_stats(params: dict, cfg: InsituConfig,
                            step: int) -> dict[str, jax.Array]:
    """Cluster sampled embedding rows; many clustered rows => collapsing
    representations (the 'halo finding' of the representation space)."""
    key = jax.random.PRNGKey(step)
    rows = _sample_rows(key, params["embed"], cfg.sample_rows)
    pts = _project(jax.random.fold_in(key, 1), rows, cfg.project_dim)
    eps = _eps_from_quantile(pts, cfg.eps_quantile)
    res = fdbscan(pts, eps, cfg.min_pts)
    n_clusters = union_find.compress(
        jnp.where(res.labels >= 0, res.labels, jnp.arange(res.labels.shape[0])))
    n_clustered = jnp.sum(res.labels >= 0)
    num_clusters = jnp.sum((res.labels == jnp.arange(res.labels.shape[0]))
                           & (res.labels >= 0))
    return {
        "insitu/embed_eps": eps,
        "insitu/embed_clustered_frac": n_clustered / res.labels.shape[0],
        "insitu/embed_num_clusters": num_clusters,
        "insitu/embed_union_rounds": res.num_rounds,
    }


def router_cluster_stats(params: dict, cfg: InsituConfig, step: int,
                         router_path=("layers",)) -> dict[str, jax.Array]:
    """Cluster MoE expert router columns (d_model -> n_experts): experts
    whose columns land in one ε-cluster are redundant (expert collapse)."""
    routers = []

    def visit(path, leaf):
        if "router" in jax.tree_util.keystr(path):
            w = leaf
            if w.ndim == 3:      # scan-stacked (G, D, E): take mean over G
                w = w.mean(axis=0)
            routers.append(w)

    jax.tree_util.tree_map_with_path(visit, params)
    if not routers:
        return {}
    cols = jnp.concatenate([w.T.astype(jnp.float32) for w in routers])  # (E*, D)
    key = jax.random.PRNGKey(step + 7)
    pts = _project(key, cols, cfg.project_dim)
    eps = _eps_from_quantile(pts, 0.05)
    res = fdbscan(pts, eps, 2)
    collapsed = jnp.sum(res.labels >= 0)
    return {
        "insitu/router_eps": eps,
        "insitu/router_collapsed_experts": collapsed,
    }


class InsituAnalyzer:
    """Hooked into the supervisor loop: runs at the configured cadence."""

    def __init__(self, cfg: InsituConfig):
        self.cfg = cfg
        self.history: list[tuple[int, dict]] = []

    def maybe_run(self, params: dict, step: int) -> dict[str, Any]:
        if step % self.cfg.cadence != 0:
            return {}
        stats = dict(embedding_cluster_stats(params, self.cfg, step))
        stats.update(router_cluster_stats(params, self.cfg, step))
        host = {k: float(np.asarray(v)) for k, v in stats.items()}
        self.history.append((step, host))
        return host
