"""In-situ analysis: the paper's contribution embedded in the training loop.

HACC pattern (paper §2): the simulation timesteps on the accelerators and,
every K long-range-force steps, runs FOF/DBSCAN halo finding in-situ —
ArborX made that step ~10x faster so analysis now runs at full cadence.

Our framework's analog: every ``cadence`` optimizer steps, run DBSCAN on
accelerator-resident point clouds derived from training state, without
leaving the device:

* embedding-space clustering — sampled token-embedding rows; detects
  representation collapse / near-duplicate embeddings (minPts=2 ≡ FOF);
* MoE router clustering — expert centroids in router space; detects expert
  collapse (experts whose router columns cluster within ε);
* simulation halo stats — for particle states (positions + velocities), the
  full HACC deliverable: labels -> halo CATALOG (``repro.halos``) with
  per-halo masses, centers and velocity dispersions, every analysis step.

All consume the SAME clustering core benchmarked in benchmarks/fig4; the
cluster accounting itself now runs through the halo-catalog subsystem
(halo-stats mode) instead of ad-hoc label arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dbscan import fdbscan
from repro.data.pipeline import hacc_benchmark_epsilon
from repro.halos.catalog import halo_catalog


@dataclasses.dataclass(frozen=True)
class InsituConfig:
    cadence: int = 10              # analysis every K steps (HACC: ~100/625)
    sample_rows: int = 512         # embedding rows sampled per analysis
    eps_quantile: float = 0.01     # ε from the pairwise-distance quantile
    min_pts: int = 2               # FOF
    project_dim: int = 3           # random projection for the geometric core
    halo_capacity: int = 256       # catalog slots for simulation halo stats
    halo_min_count: int = 10       # HACC-style small-halo mass cut
    mode: str = "training"         # "training" (embed/router) | "simulation"
    #   "simulation": state is {"positions", "velocities"[, "eps"]} and the
    #   analyzer runs the full halo-stats pipeline instead.

    def __post_init__(self):
        if self.mode not in ("training", "simulation"):
            raise ValueError(f"unknown insitu mode {self.mode!r}")


def _sample_rows(key, table: jax.Array, n: int) -> jax.Array:
    idx = jax.random.choice(key, table.shape[0], (min(n, table.shape[0]),),
                            replace=False)
    return table[idx]


def _project(key, x: jax.Array, d: int) -> jax.Array:
    """Random projection to the low-dim space the geometric core indexes
    (Johnson-Lindenstrauss: cluster structure survives)."""
    r = jax.random.normal(key, (x.shape[-1], d), jnp.float32) / np.sqrt(x.shape[-1])
    y = x.astype(jnp.float32) @ r
    lo = y.min(axis=0)
    span = jnp.maximum(y.max(axis=0) - lo, 1e-6)
    return (y - lo) / span


def _eps_from_quantile(pts: jax.Array, q: float) -> jax.Array:
    d2 = jnp.sum((pts[:, None] - pts[None]) ** 2, axis=-1)
    n = pts.shape[0]
    off = d2[jnp.triu_indices(n, 1)]
    return jnp.sqrt(jnp.quantile(off, q))


def embedding_cluster_stats(params: dict, cfg: InsituConfig,
                            step: int) -> dict[str, jax.Array]:
    """Cluster sampled embedding rows; many clustered rows => collapsing
    representations (the 'halo finding' of the representation space).

    Halo-stats mode: cluster accounting goes through the catalog subsystem.
    ``embed_num_clusters`` counts clusters that RETAIN >= min_pts members
    after border assignment (borders join only their min-root neighbor, so
    a cluster can rarely end up smaller than min_pts and is then excluded —
    a slightly stricter count than raw DBSCAN roots), and the biggest
    'halo' is reported as the sharpest collapse indicator."""
    key = jax.random.PRNGKey(step)
    rows = _sample_rows(key, params["embed"], cfg.sample_rows)
    pts = _project(jax.random.fold_in(key, 1), rows, cfg.project_dim)
    eps = _eps_from_quantile(pts, cfg.eps_quantile)
    res = fdbscan(pts, eps, cfg.min_pts)
    n = res.labels.shape[0]
    cat = halo_catalog(pts, jnp.zeros_like(pts), res.labels,
                       capacity=n, min_count=cfg.min_pts)
    n_clustered = jnp.sum(res.labels >= 0)
    return {
        "insitu/embed_eps": eps,
        "insitu/embed_clustered_frac": n_clustered / n,
        "insitu/embed_num_clusters": cat.num_halos,
        "insitu/embed_largest_cluster": jnp.max(cat.count),
        "insitu/embed_union_rounds": res.num_rounds,
    }


def simulation_halo_stats(positions: jax.Array, velocities: jax.Array,
                          cfg: InsituConfig, eps,
                          step: int = 0) -> dict[str, jax.Array]:
    """The actual HACC in-situ step: particle phase space -> halo catalog
    summary, all on-device (labels via FDBSCAN, catalog via repro.halos)."""
    res = fdbscan(positions, eps, cfg.min_pts)
    cat = halo_catalog(positions, velocities, res.labels,
                       capacity=cfg.halo_capacity,
                       min_count=cfg.halo_min_count)
    valid = cat.count > 0
    nh = jnp.maximum(cat.num_halos, 1)
    return {
        "insitu/halo_num": cat.num_halos,
        "insitu/halo_overflow": cat.overflow.astype(jnp.int32),
        "insitu/halo_largest": jnp.max(cat.count),
        "insitu/halo_mass_frac": jnp.sum(cat.count) / positions.shape[0],
        "insitu/halo_vdisp_mean": jnp.sum(jnp.where(valid, cat.vdisp, 0.0)) / nh,
        "insitu/halo_rmax_max": jnp.max(cat.rmax),
        "insitu/halo_union_rounds": res.num_rounds,
    }


def router_cluster_stats(params: dict, cfg: InsituConfig, step: int,
                         router_path=("layers",)) -> dict[str, jax.Array]:
    """Cluster MoE expert router columns (d_model -> n_experts): experts
    whose columns land in one ε-cluster are redundant (expert collapse)."""
    routers = []

    def visit(path, leaf):
        if "router" in jax.tree_util.keystr(path):
            w = leaf
            if w.ndim == 3:      # scan-stacked (G, D, E): take mean over G
                w = w.mean(axis=0)
            routers.append(w)

    jax.tree_util.tree_map_with_path(visit, params)
    if not routers:
        return {}
    cols = jnp.concatenate([w.T.astype(jnp.float32) for w in routers])  # (E*, D)
    key = jax.random.PRNGKey(step + 7)
    pts = _project(key, cols, cfg.project_dim)
    eps = _eps_from_quantile(pts, 0.05)
    res = fdbscan(pts, eps, 2)
    collapsed = jnp.sum(res.labels >= 0)
    return {
        "insitu/router_eps": eps,
        "insitu/router_collapsed_experts": collapsed,
    }


class InsituAnalyzer:
    """Hooked into the supervisor loop: runs at the configured cadence.

    ``tracer`` (a ``repro.obs.SpanTracer``) puts each analysis under a
    fenced ``insitu[step]`` span with one child span per stage (cluster
    stats, router stats, host readback), so a Perfetto trace of the
    training loop shows exactly what the in-situ cadence costs — the
    quantity the paper's §2 "analysis at full cadence" claim is about."""

    def __init__(self, cfg: InsituConfig, tracer=None):
        self.cfg = cfg
        self.tracer = tracer
        self.history: list[tuple[int, dict]] = []

    def _analyze(self, params: dict, step: int) -> dict[str, jax.Array]:
        from repro.obs.trace import traced

        if self.cfg.mode == "simulation":
            # Simulation state (the HACC workload): full halo-stats mode.
            eps = params.get("eps", hacc_benchmark_epsilon(
                1.0, int(params["positions"].shape[0])))
            return dict(traced(
                self.tracer, "insitu/halo_stats", simulation_halo_stats,
                params["positions"], params["velocities"], self.cfg, eps,
                step))
        stats = dict(traced(self.tracer, "insitu/embed_stats",
                            embedding_cluster_stats, params, self.cfg, step))
        stats.update(traced(self.tracer, "insitu/router_stats",
                            router_cluster_stats, params, self.cfg, step))
        return stats

    def maybe_run(self, params: dict, step: int) -> dict[str, Any]:
        from repro.obs.trace import traced

        if step % self.cfg.cadence != 0:
            return {}
        if self.tracer is None:
            stats = self._analyze(params, step)
            host = {k: float(np.asarray(v)) for k, v in stats.items()}
        else:
            with self.tracer.span("insitu", step=step, mode=self.cfg.mode):
                stats = self._analyze(params, step)
                host = traced(
                    self.tracer, "insitu/host_readback",
                    lambda: {k: float(np.asarray(v))
                             for k, v in stats.items()})
        self.history.append((step, host))
        return host
