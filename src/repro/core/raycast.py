"""Basic ray casting (paper §3.2: "ArborX provides basic support for ray
tracing"): nearest AABB hit per ray, as a thin client of the unified query
engine — the ``ray`` predicate dispatched through ``core.query.query``
(slab-method intersection + ordered stack traversal pruning by the current
best entry t, all inside the engine).

Leaves are boxed objects (build the BVH with `build_bvh_objects`); returns
the nearest-entry leaf for each ray (index + t), or (-1, inf) on miss.

``raycast_all`` is the all-intersections mode: every leaf each ray pierces,
streamed through the device-resident CSR output protocol (no host sync with
``capacity=``; hits per ray at ``indices[offsets[i]:offsets[i+1]]``)."""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax

from repro.core.bvh import Bvh
from repro.core.query import (DeviceCsr, query, query_csr, ray as _ray)

__all__ = ["RayHits", "raycast", "raycast_all"]


class RayHits(NamedTuple):
    index: jax.Array   # (r,) int32 — original object index (-1 = miss)
    t: jax.Array       # (r,) float32 — entry parameter along the ray


@jax.jit
def raycast(bvh: Bvh, origins: jax.Array, directions: jax.Array) -> RayHits:
    """Nearest hit for each ray. origins/directions: (r, d)."""
    res = query(bvh, _ray(origins, directions))
    return RayHits(index=res.index, t=res.t)


def raycast_all(bvh: Bvh, origins: jax.Array, directions: jax.Array, *,
                capacity: int | None = None, chunk: int = 32,
                backend: str = "stackless",
                sort_queries: bool = False) -> DeviceCsr:
    """ALL leaf intersections per ray (unordered within a row), as CSR.

    With ``capacity=`` the whole thing is device-resident and jit-traceable
    (overflow hits past capacity are dropped and flagged); with
    ``capacity=None`` one host sync sizes the result exactly. Rays with
    t ≥ 0: intersections behind the origin don't count."""
    return query_csr(bvh, _ray(origins, directions), capacity=capacity,
                     chunk=chunk, backend=backend, sort_queries=sort_queries)
