"""Basic ray casting (paper §3.2: "ArborX provides basic support for ray
tracing"): nearest AABB hit per ray via ordered stack traversal.

Leaves are boxed objects (build the BVH with `build_bvh_objects`); returns
the nearest-entry leaf for each ray (index + t), or (-1, inf) on miss.
Slab-method ray/AABB intersection; traversal prunes nodes whose entry t
exceeds the current best."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bvh import Bvh, SENTINEL

_STACK_DEPTH = 96

__all__ = ["RayHits", "raycast"]


class RayHits(NamedTuple):
    index: jax.Array   # (r,) int32 — original object index (-1 = miss)
    t: jax.Array       # (r,) float32 — entry parameter along the ray


def _ray_box(origin, inv_dir, lo, hi):
    """Slab test. Returns (t_entry, hit) with t_entry >= 0."""
    t0 = (lo - origin) * inv_dir
    t1 = (hi - origin) * inv_dir
    tmin = jnp.max(jnp.minimum(t0, t1))
    tmax = jnp.min(jnp.maximum(t0, t1))
    hit = (tmax >= jnp.maximum(tmin, 0.0))
    return jnp.maximum(tmin, 0.0), hit


@jax.jit
def raycast(bvh: Bvh, origins: jax.Array, directions: jax.Array) -> RayHits:
    """Nearest hit for each ray. origins/directions: (r, d)."""
    n = bvh.num_leaves

    def one(origin, direction):
        inv = 1.0 / jnp.where(jnp.abs(direction) < 1e-12,
                              jnp.sign(direction) * 1e-12 + 1e-12, direction)
        stack0 = jnp.full((_STACK_DEPTH,), SENTINEL, jnp.int32).at[0].set(0)

        def cond(state):
            return state[0] > 0

        def body(state):
            sp, stack, best_t, best_i = state
            node = stack[sp - 1]
            sp = sp - 1
            is_leaf = node >= n - 1
            t_in, hit = _ray_box(origin, inv, bvh.node_lo[node],
                                 bvh.node_hi[node])
            closer = hit & (t_in < best_t)

            sorted_idx = jnp.clip(node - (n - 1), 0, n - 1)
            orig = bvh.leaf_perm[sorted_idx]
            take = is_leaf & closer
            best_i = jnp.where(take, orig, best_i)
            best_t = jnp.where(take, t_in, best_t)

            node_c = jnp.clip(node, 0, n - 2)
            for child in (bvh.right_child[node_c], bvh.left_child[node_c]):
                tc, hc = _ray_box(origin, inv, bvh.node_lo[child],
                                  bvh.node_hi[child])
                push = (~is_leaf) & closer & hc & (tc < best_t)
                stack = stack.at[sp].set(jnp.where(push, child, stack[sp]))
                sp = sp + push.astype(jnp.int32)
            return sp, stack, best_t, best_i

        _, _, best_t, best_i = jax.lax.while_loop(
            cond, body, (jnp.int32(1), stack0, jnp.float32(jnp.inf),
                         jnp.int32(-1)))
        return best_i, best_t

    idx, t = jax.vmap(one)(origins, directions)
    return RayHits(index=idx, t=t)
