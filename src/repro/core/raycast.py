"""Basic ray casting (paper §3.2: "ArborX provides basic support for ray
tracing"): nearest AABB hit per ray, as a thin client of the unified query
engine — the ``ray`` predicate dispatched through ``core.query.query``
(slab-method intersection + ordered stack traversal pruning by the current
best entry t, all inside the engine).

Leaves are boxed objects (build the BVH with `build_bvh_objects`); returns
the nearest-entry leaf for each ray (index + t), or (-1, inf) on miss."""
from __future__ import annotations

from typing import NamedTuple

import jax

from repro.core.bvh import Bvh
from repro.core.query import query, ray as _ray

__all__ = ["RayHits", "raycast"]


class RayHits(NamedTuple):
    index: jax.Array   # (r,) int32 — original object index (-1 = miss)
    t: jax.Array       # (r,) float32 — entry parameter along the ray


@jax.jit
def raycast(bvh: Bvh, origins: jax.Array, directions: jax.Array) -> RayHits:
    """Nearest hit for each ray. origins/directions: (r, d)."""
    res = query(bvh, _ray(origins, directions))
    return RayHits(index=res.index, t=res.t)
