"""Two-point correlation pair counts (paper §4.2.3: "the operation could be
... to increase the value of the count (e.g., computing 2-point
correlations)") — the other HACC analysis kernel, built on the SAME pair
traversal: each unordered pair within r_max is visited exactly once and
binned by distance.

Returns DD(r) pair counts per radial bin; the Landy-Szalay estimator is a
host-side postprocess (needs an RR reference count).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bvh import build_bvh
from repro.core.geometry import scene_bounds
from repro.core.query import query, within

__all__ = ["pair_count_histogram", "two_point_correlation"]


@partial(jax.jit, static_argnames=("n_bins",))
def pair_count_histogram(points: jax.Array, r_max, n_bins: int = 16) -> jax.Array:
    """DD(r): counts of unordered pairs with dist in each of n_bins equal
    bins over (0, r_max]. A fused engine callback on the pair backend — no
    pair list is ever materialized (the paper's callback principle), and
    the engine hands the callback the squared pair distance directly."""
    lo, hi = scene_bounds(points)
    bvh = build_bvh(points, lo, hi)
    r_max_f = jnp.asarray(r_max, points.dtype)

    def fn(hist, i, j, d2):
        b = jnp.floor(jnp.sqrt(jnp.maximum(d2, 1e-30)) / r_max_f * n_bins)
        b = jnp.clip(b.astype(jnp.int32), 0, n_bins - 1)
        return hist.at[b].add(1), jnp.bool_(False)

    hist0 = jnp.zeros((n_bins,), jnp.int32)
    per_query = query(bvh, within(points, r_max_f), fn, hist0, backend="pair")
    return jnp.sum(per_query, axis=0)


def two_point_correlation(points, r_max, n_bins: int = 16, *, volume: float = 1.0):
    """ξ(r) via the natural estimator DD/RR - 1 with an analytic uniform RR
    (periodic-free approximation; fine for r_max << box size)."""
    import numpy as np
    dd = np.asarray(pair_count_histogram(points, r_max, n_bins), np.float64)
    n = points.shape[0]
    edges = np.linspace(0.0, float(r_max), n_bins + 1)
    shell = 4.0 / 3.0 * np.pi * (edges[1:] ** 3 - edges[:-1] ** 3)
    rr = n * (n - 1) / 2.0 * shell / volume
    with np.errstate(divide="ignore", invalid="ignore"):
        xi = np.where(rr > 0, dd / rr - 1.0, 0.0)
    return xi, dd, edges
