"""Distributed DBSCAN over a device mesh axis (paper §2/C9 — HACC's MPI
domain decomposition expressed in shard_map + collectives).

Pattern (mirrors HACC's per-rank FOF):
  1. Slab domain decomposition: shard k owns the k-th contiguous slab along
     the first coordinate (the driver pre-partitions; see
     ``slab_partition``).
  2. ε-halo exchange: each shard packs its boundary points (within ε of a
     slab face) into fixed-capacity buffers and ships them to the adjacent
     shards with ``ppermute`` (the MPI ghost-zone exchange).
  3. Local clustering over local ∪ halo points (brute-force ε-graph here —
    the per-shard index choice is orthogonal; production uses the kernels).
  4. Iterative global label merge: boundary labels are re-exchanged and
     hook/compressed until a global fixpoint (``psum`` of the change flag) —
     the distributed union-find rounds of §4.3.

Labels are GLOBAL point ids (shard * n_local + slot); cluster root = the
minimum global id in the cluster, noise = -1. Fixed shapes everywhere.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

NOISE = jnp.int32(-1)
BIG = 1e15


class DistDbscanResult(NamedTuple):
    labels: jax.Array      # (n_total,) global labels, sharded like points
    core_mask: jax.Array
    rounds: jax.Array      # () int32 global merge rounds
    halo_overflow: jax.Array  # () bool — halo capacity exceeded somewhere


def slab_partition(points: np.ndarray, n_shards: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side pre-partition: sort by x and split into equal slabs (HACC
    ranks own spatial subvolumes). Returns (points_sorted, orig_index)."""
    order = np.argsort(points[:, 0], kind="stable")
    return points[order], order


def _pack_boundary(pts: jax.Array, mask: jax.Array, cap: int):
    """Pack masked rows into a fixed (cap, d) buffer (+global slot ids)."""
    n = pts.shape[0]
    order = jnp.argsort(~mask, stable=True)  # masked rows first
    idx = order[:cap]
    valid = mask[idx]
    buf = jnp.where(valid[:, None], pts[idx], BIG)
    count = jnp.sum(mask.astype(jnp.int32))
    return buf, idx, valid, count > cap


def _neighbor_counts(x: jax.Array, y: jax.Array, eps2) -> jax.Array:
    d2 = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    return jnp.sum(d2 <= eps2, axis=1).astype(jnp.int32)


def _min_core_label(x: jax.Array, y: jax.Array, labels: jax.Array,
                    core: jax.Array, eps2, sentinel: int) -> jax.Array:
    d2 = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    ok = (d2 <= eps2) & core[None, :]
    return jnp.min(jnp.where(ok, labels[None, :], sentinel), axis=1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("min_pts", "halo_cap", "axis", "mesh_ref",
                                    "max_rounds"))
def _dbscan_sharded(points, eps, min_pts, halo_cap, axis, mesh_ref, max_rounds):
    mesh = mesh_ref.mesh
    n_shards = mesh.shape[axis]
    eps2 = jnp.asarray(eps, jnp.float32) ** 2

    def local_fn(pts):
        pts = pts[0]                                  # drop leading shard dim
        n_loc = pts.shape[0]
        me = jax.lax.axis_index(axis)
        gid = me * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
        sentinel = jnp.int32(n_shards * n_loc)

        # --- slab bounds from local extrema (slabs are contiguous in x) ----
        lo_x = jnp.min(pts[:, 0])
        hi_x = jnp.max(pts[:, 0])

        # --- halo exchange (points + global ids) ---------------------------
        left_mask = pts[:, 0] <= lo_x + eps
        right_mask = pts[:, 0] >= hi_x - eps
        lbuf, lidx, lvalid, lovf = _pack_boundary(pts, left_mask, halo_cap)
        rbuf, ridx, rvalid, rovf = _pack_boundary(pts, right_mask, halo_cap)

        right_perm = [(i, i + 1) for i in range(n_shards - 1)]
        left_perm = [(i + 1, i) for i in range(n_shards - 1)]

        def xchg(val_r, val_l):
            """send val_r to the right neighbor, val_l to the left. Devices
            with no sender (slab edges) receive ZEROS — all exchanged payloads
            are therefore encoded so 0 means 'absent'."""
            from_left = jax.lax.ppermute(val_r, axis, right_perm)
            from_right = jax.lax.ppermute(val_l, axis, left_perm)
            return from_left, from_right

        # gid encoded +1 so the zero-fill at slab edges decodes to 'absent'.
        lgid_enc = jnp.where(lvalid, gid[lidx] + 1, 0)
        rgid_enc = jnp.where(rvalid, gid[ridx] + 1, 0)
        halo_l_pts, halo_r_pts = xchg(rbuf, lbuf)
        halo_l_enc, halo_r_enc = xchg(rgid_enc, lgid_enc)
        halo_enc = jnp.concatenate([halo_l_enc, halo_r_enc])
        halo_ok = halo_enc > 0
        halo_pts = jnp.where(halo_ok[:, None],
                             jnp.concatenate([halo_l_pts, halo_r_pts]), BIG)

        all_pts = jnp.concatenate([pts, halo_pts])                 # (n+2H, d)

        # --- core classification -------------------------------------------
        counts = _neighbor_counts(pts, all_pts, eps2)
        core = counts >= min_pts
        # halo core flags: owners compute, then exchange along the same route
        lcore = (lvalid & core[lidx]).astype(jnp.int32)
        rcore = (rvalid & core[ridx]).astype(jnp.int32)
        halo_l_core, halo_r_core = xchg(rcore, lcore)
        halo_core = jnp.concatenate([halo_l_core, halo_r_core]) > 0
        all_core = jnp.concatenate([core, halo_core & halo_ok])

        # --- local union-find: collapse local components to roots ----------
        # (pure min-label propagation needs O(cluster diameter) rounds; with
        # local components collapsed, the global fixpoint needs only one
        # round per shard boundary the cluster crosses.)
        d2_local = jnp.sum((pts[:, None, :] - pts[None, :, :]) ** 2, axis=-1)
        adj_local = (d2_local <= eps2) & core[:, None] & core[None, :]
        ii = jnp.broadcast_to(jnp.arange(n_loc, dtype=jnp.int32)[:, None],
                              (n_loc, n_loc)).reshape(-1)
        jj = jnp.broadcast_to(jnp.arange(n_loc, dtype=jnp.int32)[None, :],
                              (n_loc, n_loc)).reshape(-1)
        from repro.core import union_find as _uf
        local_root = _uf.connected_components(n_loc, ii, jj,
                                              adj_local.reshape(-1))

        # --- distributed union fixpoint over ROOT labels --------------------
        labels0 = jnp.where(core, gid[local_root], sentinel).astype(jnp.int32)

        def halo_labels(labels):
            """Exchange current labels of the (fixed) boundary sets; +1
            encoding so edge zero-fill decodes to sentinel."""
            ll = jnp.where(lvalid, labels[lidx] + 1, 0)
            rl = jnp.where(rvalid, labels[ridx] + 1, 0)
            hl, hr = xchg(rl, ll)
            enc = jnp.concatenate([hl, hr])
            return jnp.where(enc > 0, enc - 1, sentinel)

        def cond(state):
            _, changed, r = state
            return changed & (r < max_rounds)

        def body(state):
            labels, _, r = state
            all_labels = jnp.concatenate([labels, halo_labels(labels)])
            m = _min_core_label(pts, all_pts, all_labels, all_core, eps2,
                                sentinel)
            m = jnp.where(core, jnp.minimum(labels, m), sentinel)
            # scatter the min onto the LOCAL root, then broadcast back
            root_min = jnp.full((n_loc,), sentinel, jnp.int32) \
                .at[local_root].min(m)
            new = jnp.where(core, root_min[local_root], labels).astype(jnp.int32)
            changed_local = jnp.any(new != labels)
            changed = jax.lax.psum(changed_local.astype(jnp.int32), axis) > 0
            return new, changed, r + 1

        # psum-derived init: INVARIANT vma, matching the body's psum output
        changed0 = jax.lax.psum(jnp.int32(1), axis) > 0
        labels, _, rounds = jax.lax.while_loop(
            cond, body, (labels0, changed0, jnp.int32(0)))

        # --- border points ---------------------------------------------------
        all_labels = jnp.concatenate([labels, halo_labels(labels)])
        border = _min_core_label(pts, all_pts, all_labels, all_core, eps2,
                                 sentinel)
        final = jnp.where(core, labels,
                          jnp.where(border < sentinel, border, NOISE))
        final = jnp.where(final == sentinel, NOISE, final)

        ovf = jax.lax.psum((lovf | rovf).astype(jnp.int32), axis) > 0
        return (final[None], core[None], rounds[None], ovf[None])

    spec_in = P(axis, None)
    # check_rep=False: the body contains while_loops (union fixpoint, local
    # CC), for which shard_map has no replication rule on some JAX versions.
    labels, core, rounds, ovf = shard_map(
        local_fn, mesh=mesh, in_specs=(spec_in,),
        out_specs=(P(axis), P(axis), P(axis), P(axis)),
        check_rep=False,
    )(points.reshape(n_shards, -1, points.shape[-1]))
    return (labels.reshape(-1), core.reshape(-1), jnp.max(rounds),
            jnp.any(ovf))


def dbscan_distributed(points: jax.Array, eps, min_pts: int, *, mesh: Mesh,
                       axis: str = "data", halo_cap: int = 512,
                       max_rounds: int = 64) -> DistDbscanResult:
    """points: (n_total, d), n_total divisible by the axis size, pre-sorted
    by x (``slab_partition``) so shard slabs are contiguous."""

    class _Ref:
        def __init__(self, m):
            self.mesh = m

        def __hash__(self):
            return hash(id(self.mesh))

        def __eq__(self, other):
            return self.mesh is getattr(other, "mesh", None)

    labels, core, rounds, ovf = _dbscan_sharded(
        points, eps, min_pts, halo_cap, axis, _Ref(mesh), max_rounds)
    return DistDbscanResult(labels=labels, core_mask=core, rounds=rounds,
                            halo_overflow=ovf)
