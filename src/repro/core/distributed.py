"""Sharded geometric queries over a device mesh axis (paper §2/C9 — HACC's
MPI domain decomposition expressed in shard_map + collectives).

The file is layered so every sharded consumer (distributed DBSCAN, the halo
pipeline in ``repro.halos``, user query code) shares one substrate:

  1. ``slab_partition`` — host-side pre-partition: shard k owns the k-th
     contiguous slab along the first coordinate.
  2. ``halo_exchange`` — the ε-ghost exchange: each shard packs its boundary
     points (within ε of a slab face) into fixed-capacity buffers and ships
     them to the adjacent shards with ``ppermute`` (the MPI ghost-zone
     exchange). The routes are FIXED, so ``exchange_payload`` can later ship
     any per-point value (core flags, labels) along them without re-packing.
  3. ``shard_context`` — per-shard BVHs: one over local ∪ ghost points (cross-
     shard queries) and one over local points only (local union rounds, SO
     profiles). Invalid ghost rows are folded to a coordinate ≥ 4ε outside
     the local scene so they can never satisfy an ε-predicate AND never
     poison the Morton normalization (a BIG=1e15 fill would collapse every
     real point into one Morton bin — see ROADMAP item 3).
  4. ``sharded_query_csr`` / ``sharded_neighbor_csr`` — cross-shard queries
     through the device-resident CSR protocol (``query_csr_device``): per-
     shard build → exchange → traversal → scatter, all inside one
     ``shard_map`` region with zero host round-trips.
  5. ``dbscan_local_shard`` — the per-shard DBSCAN body (engine traversals,
     not dense O(n²) matrices), callable inside ANY shard_map region so
     larger pipelines (``repro.halos.merge.halo_pipeline_sharded``) can fuse
     clustering with catalog construction.
  6. ``dbscan_distributed`` — the standalone driver, same API as before.

Labels are GLOBAL point ids (shard * n_local + slot); cluster root = the
minimum global id in the cluster, noise = -1. Fixed shapes everywhere.
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.bvh import Bvh, build_bvh
from repro.core.dbscan import count_neighbors, min_core_label_on, union_rounds
from repro.core.geometry import scene_bounds
from repro.core.query import (DeviceCsr, _canon_index_dtype,
                              query_csr_device, within)

__all__ = [
    "NOISE",
    "DistDbscanResult",
    "HaloExchange",
    "ShardContext",
    "ShardedCsr",
    "slab_partition",
    "halo_exchange",
    "exchange_payload",
    "shard_context",
    "sharded_query_csr",
    "sharded_neighbor_csr",
    "dbscan_local_shard",
    "dbscan_distributed",
]

NOISE = jnp.int32(-1)
BIG = 1e15


class DistDbscanResult(NamedTuple):
    labels: jax.Array      # (n_total,) global labels, sharded like points
    core_mask: jax.Array
    rounds: jax.Array      # () int32 global merge rounds
    halo_overflow: jax.Array  # () bool — halo capacity exceeded somewhere


class HaloExchange(NamedTuple):
    """Result of the ε-ghost exchange, with the fixed boundary routes kept so
    per-point payloads can be re-shipped later (``exchange_payload``)."""
    halo_pts: jax.Array    # (2H, d) ghost points; invalid rows folded ≥4ε out
    halo_valid: jax.Array  # (2H,) bool
    halo_gid: jax.Array    # (2H,) global ids (dtype follows gid), -1 invalid
    overflow: jax.Array    # () bool — any shard overflowed its halo buffer
    lidx: jax.Array        # (H,) local rows packed for the LEFT neighbor
    lvalid: jax.Array      # (H,) bool
    ridx: jax.Array        # (H,) local rows packed for the RIGHT neighbor
    rvalid: jax.Array      # (H,) bool
    n_shards: int          # python int — rebuilds the ppermute routes


class ShardContext(NamedTuple):
    """Per-shard sharded-query substrate (build once, query many). Global
    ids carry the caller's ``index_dtype`` — int64 (under x64) once
    ``n_shards * n_loc`` can exceed 2^31 (staticcheck rule W1)."""
    gid: jax.Array       # (n_loc,) index_dtype global ids of local points
    exchange: HaloExchange
    all_pts: jax.Array   # (n_loc + 2H, d) local ∪ ghost
    all_gid: jax.Array   # (n_loc + 2H,) index_dtype, -1 on invalid ghost rows
    bvh_all: Bvh         # tree over local ∪ ghost (cross-shard queries)
    bvh_local: Bvh       # tree over local points only
    sentinel: jax.Array  # () index_dtype = n_shards * n_loc (> any global id)


class ShardedCsr(NamedTuple):
    """Cross-shard CSR: per-shard rows over LOCAL queries, global object ids
    (offsets/indices/total carry the caller's ``index_dtype``)."""
    offsets: jax.Array     # (S, n_loc+1) per-shard row starts
    indices: jax.Array     # (S, capacity) GLOBAL point ids, -1 padded
    total: jax.Array       # (S,) hits per shard
    overflowed: jax.Array  # () bool — any shard exceeded ``capacity``


def slab_partition(points: np.ndarray, n_shards: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side pre-partition: sort by x and split into equal slabs (HACC
    ranks own spatial subvolumes). Returns (points_sorted, orig_index)."""
    order = np.argsort(points[:, 0], kind="stable")
    return points[order], order


def _pack_boundary(pts: jax.Array, mask: jax.Array, cap: int):
    """Pack masked rows into a fixed (cap, d) buffer (+global slot ids)."""
    n = pts.shape[0]
    order = jnp.argsort(~mask, stable=True)  # masked rows first
    idx = order[:cap]
    valid = mask[idx]
    buf = jnp.where(valid[:, None], pts[idx], BIG)
    count = jnp.sum(mask.astype(jnp.int32))
    return buf, idx, valid, count > cap


def _perms(n_shards: int):
    right_perm = [(i, i + 1) for i in range(n_shards - 1)]
    left_perm = [(i + 1, i) for i in range(n_shards - 1)]
    return right_perm, left_perm


def _xchg(axis: str, n_shards: int, val_r, val_l):
    """Send ``val_r`` to the right neighbor, ``val_l`` to the left. Devices
    with no sender (slab edges) receive ZEROS — all exchanged payloads are
    therefore decoded through a validity mask (or 0-means-absent encoding)."""
    right_perm, left_perm = _perms(n_shards)
    from_left = jax.lax.ppermute(val_r, axis, right_perm)
    from_right = jax.lax.ppermute(val_l, axis, left_perm)
    return from_left, from_right


def halo_exchange(pts: jax.Array, gid: jax.Array, eps, halo_cap: int,
                  axis: str, n_shards: int) -> HaloExchange:
    """The ε-ghost exchange (call inside a shard_map region): ship boundary
    points + their global ids to the adjacent shards along fixed routes.

    Invalid ghost rows (slab-edge fill, overflow padding) are folded to a
    point ≥ 4ε beyond the per-dim max of every real point this shard can see,
    so downstream ε-queries never match them and BVH quality is preserved."""
    eps = jnp.asarray(eps, pts.dtype)
    lo_x = jnp.min(pts[:, 0])
    hi_x = jnp.max(pts[:, 0])
    left_mask = pts[:, 0] <= lo_x + eps
    right_mask = pts[:, 0] >= hi_x - eps
    lbuf, lidx, lvalid, lovf = _pack_boundary(pts, left_mask, halo_cap)
    rbuf, ridx, rvalid, rovf = _pack_boundary(pts, right_mask, halo_cap)

    halo_l_pts, halo_r_pts = _xchg(axis, n_shards, rbuf, lbuf)
    # gid encoded +1 so the zero-fill at slab edges decodes to 'absent'.
    lgid_enc = jnp.where(lvalid, gid[lidx] + 1, 0)
    rgid_enc = jnp.where(rvalid, gid[ridx] + 1, 0)
    halo_l_enc, halo_r_enc = _xchg(axis, n_shards, rgid_enc, lgid_enc)
    halo_enc = jnp.concatenate([halo_l_enc, halo_r_enc])
    halo_valid = halo_enc > 0
    halo_gid = jnp.where(halo_valid, halo_enc - 1, -1).astype(gid.dtype)

    raw = jnp.concatenate([halo_l_pts, halo_r_pts])
    ghost_hi = jnp.max(jnp.where(halo_valid[:, None], raw,
                                 -jnp.inf).astype(pts.dtype), axis=0)
    ghost_lo = jnp.min(jnp.where(halo_valid[:, None], raw,
                                 jnp.inf).astype(pts.dtype), axis=0)
    hi_all = jnp.maximum(jnp.max(pts, axis=0), ghost_hi)
    lo_all = jnp.minimum(jnp.min(pts, axis=0), ghost_lo)
    span = jnp.max(hi_all - lo_all)
    fold = hi_all + 4.0 * eps + 1e-3 * span + 1e-6
    halo_pts = jnp.where(halo_valid[:, None], raw, fold)

    ovf = jax.lax.psum((lovf | rovf).astype(jnp.int32), axis) > 0
    return HaloExchange(halo_pts=halo_pts, halo_valid=halo_valid,
                        halo_gid=halo_gid, overflow=ovf,
                        lidx=lidx, lvalid=lvalid, ridx=ridx, rvalid=rvalid,
                        n_shards=n_shards)


def exchange_payload(ex: HaloExchange, values: jax.Array, fill,
                     axis: str) -> jax.Array:
    """Ship per-point ``values`` of the fixed boundary sets along the same
    routes the points took; rows with no sender (slab edges, overflow
    padding) decode to ``fill``. Returns (2H,) aligned with ``ex.halo_pts``."""
    fill = jnp.asarray(fill, values.dtype)
    lv = jnp.where(ex.lvalid, values[ex.lidx], fill)
    rv = jnp.where(ex.rvalid, values[ex.ridx], fill)
    hl, hr = _xchg(axis, ex.n_shards, rv, lv)
    out = jnp.concatenate([hl, hr])
    return jnp.where(ex.halo_valid, out, fill)


def shard_context(pts: jax.Array, eps, halo_cap: int, axis: str,
                  n_shards: int, *, use_64bit: bool = True,
                  index_dtype=jnp.int32) -> ShardContext:
    """Build the per-shard sharded-query substrate (call inside a shard_map
    region): ε-ghost exchange, then BVHs over local ∪ ghost and local-only
    points. Everything downstream — cross-shard CSR queries, distributed
    DBSCAN, catalog merge — runs off this context with no further host
    involvement. ``index_dtype`` sets the global-id dtype — int64 (under
    x64) once ``n_shards * n_loc`` can exceed 2^31."""
    idx_dt = _canon_index_dtype(index_dtype)
    n_loc = pts.shape[0]
    me = jax.lax.axis_index(axis).astype(idx_dt)
    gid = me * n_loc + jnp.arange(n_loc, dtype=idx_dt)
    ex = halo_exchange(pts, gid, eps, halo_cap, axis, n_shards)

    all_pts = jnp.concatenate([pts, ex.halo_pts])
    all_gid = jnp.concatenate([gid, ex.halo_gid])
    lo, hi = scene_bounds(all_pts)
    bvh_all = build_bvh(all_pts, lo, hi, use_64bit=use_64bit)
    lo_l, hi_l = scene_bounds(pts)
    bvh_local = build_bvh(pts, lo_l, hi_l, use_64bit=use_64bit)
    return ShardContext(gid=gid, exchange=ex, all_pts=all_pts,
                        all_gid=all_gid, bvh_all=bvh_all, bvh_local=bvh_local,
                        sentinel=jnp.asarray(n_shards * n_loc, idx_dt))


def sharded_query_csr(ctx: ShardContext, predicates, capacity: int, *,
                      axis: str, chunk: int = 32,
                      backend: str = "stackless") -> DeviceCsr:
    """Cross-shard device CSR (call inside a shard_map region): run the
    predicates against this shard's local ∪ ghost tree and remap hit indices
    to GLOBAL point ids (dtype follows ``ctx.gid``). No host sync — the
    result stays on device."""
    idx_dt = ctx.gid.dtype
    res = query_csr_device(ctx.bvh_all, predicates, capacity,
                           chunk=chunk, backend=backend, index_dtype=idx_dt)
    n_all = ctx.all_gid.shape[0]
    safe = jnp.clip(res.indices, 0, n_all - 1)
    gidx = jnp.where(res.indices >= 0, ctx.all_gid[safe], -1).astype(idx_dt)
    return DeviceCsr(offsets=res.offsets, indices=gidx, total=res.total,
                     overflowed=res.overflowed)


def _jit_ok() -> bool:
    """Whether shard_map drivers may run under one jitted SPMD program.

    XLA:CPU's collective rendezvous busy-spins: every simulated device in a
    jitted shard_map program needs a core of its own, or a rank still inside
    a long traversal while_loop is starved by a peer spinning at a
    ``ppermute`` and the program deadlocks (the "waiting for all participants
    to arrive at rendezvous" hang). When the host has fewer cores than local
    devices, fall back to eager shard_map — per-primitive dispatch completes
    each collective before the next op is launched and never spins.
    Override with ``REPRO_SHARDED_JIT=0|1``.
    """
    env = os.environ.get("REPRO_SHARDED_JIT")
    if env is not None:
        return env not in ("0", "false", "False")
    if jax.default_backend() != "cpu":
        return True
    return (os.cpu_count() or 1) >= jax.local_device_count()


def _maybe_jit(fn, *, static_argnames):
    """``jax.jit`` for shard_map drivers, gated per call by ``_jit_ok``."""
    jitted = jax.jit(fn, static_argnames=static_argnames)

    @functools.wraps(fn)
    def run(*args, **kwargs):
        return (jitted if _jit_ok() else fn)(*args, **kwargs)

    return run


def _mesh_ref(mesh: Mesh):
    class _Ref:
        def __init__(self, m):
            self.mesh = m

        def __hash__(self):
            return hash(id(self.mesh))

        def __eq__(self, other):
            return self.mesh is getattr(other, "mesh", None)

    return _Ref(mesh)


@functools.partial(_maybe_jit,
                   static_argnames=("capacity", "halo_cap", "axis", "mesh_ref",
                                    "chunk", "backend", "use_64bit",
                                    "index_dtype"))
def _neighbor_csr_sharded(points, eps, capacity, halo_cap, axis, mesh_ref,
                          chunk, backend, use_64bit, index_dtype):
    mesh = mesh_ref.mesh
    n_shards = mesh.shape[axis]

    def local_fn(pts):
        pts = pts[0]
        ctx = shard_context(pts, eps, halo_cap, axis, n_shards,
                            use_64bit=use_64bit, index_dtype=index_dtype)
        pred = within(pts, jnp.asarray(eps, pts.dtype))
        res = sharded_query_csr(ctx, pred, capacity, axis=axis,
                                chunk=chunk, backend=backend)
        ovf = jax.lax.psum(res.overflowed.astype(jnp.int32), axis) > 0
        halo_ovf = ctx.exchange.overflow
        return (res.offsets[None], res.indices[None], res.total[None],
                (ovf | halo_ovf)[None])

    spec_in = P(axis, None)
    offsets, indices, total, ovf = shard_map(
        local_fn, mesh=mesh, in_specs=(spec_in,),
        out_specs=(P(axis, None), P(axis, None), P(axis), P(axis)),
        check_rep=False,
    )(points.reshape(n_shards, -1, points.shape[-1]))
    return offsets, indices, total, jnp.any(ovf)


def sharded_neighbor_csr(points: jax.Array, eps, *, capacity: int, mesh: Mesh,
                         axis: str = "data", halo_cap: int = 512,
                         chunk: int = 32, backend: str = "stackless",
                         use_64bit: bool = True, index_dtype=jnp.int32,
                         tracer=None) -> ShardedCsr:
    """The reusable sharded-query layer, end to end: slab-sharded points in,
    per-shard ε-neighbor CSR out (GLOBAL point ids, self included), computed
    as per-shard BVH build → ppermute ghost exchange → device-resident CSR —
    one shard_map region, zero host round-trips.

    ``points``: (n_total, d) pre-sorted by x (``slab_partition``), n_total
    divisible by the axis size. ``capacity`` bounds hits PER SHARD.
    ``index_dtype``: global-id/offset dtype — int64 (under x64) once
    ``n_total`` or per-shard hits can exceed 2^31.

    ``tracer`` (a ``repro.obs.SpanTracer``) wraps the fused launch in one
    fenced span — the exchange/build/query phases share a single shard_map
    region by design, so the host sees them as one launch — and samples the
    per-shard hit totals onto a counter track after the fence."""
    idx_dt = _canon_index_dtype(index_dtype)
    if tracer is None:
        offsets, indices, total, ovf = _neighbor_csr_sharded(
            points, eps, int(capacity), halo_cap, axis, _mesh_ref(mesh),
            chunk, backend, use_64bit, idx_dt)
        return ShardedCsr(offsets=offsets, indices=indices, total=total,
                          overflowed=ovf)
    with tracer.span("sharded_neighbor_csr", n=int(points.shape[0]),
                     shards=int(mesh.shape[axis]), backend=backend) as sp:
        offsets, indices, total, ovf = sp.fence(_neighbor_csr_sharded(
            points, eps, int(capacity), halo_cap, axis, _mesh_ref(mesh),
            chunk, backend, use_64bit, idx_dt))
    tracer.counter("csr_hits", total=int(jnp.sum(total)),
                   overflowed=int(ovf))
    return ShardedCsr(offsets=offsets, indices=indices, total=total,
                      overflowed=ovf)


def dbscan_local_shard(pts: jax.Array, eps, min_pts: int, ctx: ShardContext,
                       *, axis: str, max_rounds: int = 64):
    """Per-shard DBSCAN body (call inside a shard_map region): engine
    traversals over the shard-context trees replace the dense O(n²) neighbor
    matrices the original implementation staged.

      - core test: ε-counts over local ∪ ghost with early exit at min_pts
      - local components: ``union_rounds`` fixpoint on the local tree
      - global merge: exchange boundary labels, min-core-label traversal,
        hook onto local roots, repeat until a ``psum`` fixpoint
      - border points: final min-core-label pass over local ∪ ghost

    Returns (labels, core_mask, rounds) for the local points; labels are
    global point ids, noise = -1."""
    n_loc = pts.shape[0]
    eps_f = jnp.asarray(eps, pts.dtype)
    ex = ctx.exchange
    sentinel = ctx.sentinel

    # --- core classification: ε-counts over local ∪ ghost ------------------
    counts = count_neighbors(ctx.bvh_all, ctx.all_pts, pts, eps_f,
                             min_pts=min_pts)
    core = counts >= min_pts
    halo_core = exchange_payload(ex, core.astype(jnp.int32), 0, axis) > 0
    all_core = jnp.concatenate([core, halo_core])

    # --- local components: union fixpoint on the local tree -----------------
    local_root, _ = union_rounds(ctx.bvh_local, pts, eps_f, core, n_loc,
                                 max_rounds=max_rounds)
    idx_dt = ctx.gid.dtype
    labels0 = jnp.where(core, ctx.gid[local_root], sentinel).astype(idx_dt)

    def halo_labels(labels):
        return exchange_payload(ex, labels, sentinel, axis)

    def cond(state):
        _, changed, r = state
        return changed & (r < max_rounds)

    def body(state):
        labels, _, r = state
        all_labels = jnp.concatenate([labels, halo_labels(labels)])
        m = min_core_label_on(ctx.bvh_all, pts, eps_f, all_labels, all_core,
                              core, sentinel)
        m = jnp.where(core, jnp.minimum(labels, m), sentinel)
        # scatter the min onto the LOCAL root, then broadcast back
        root_min = jnp.full((n_loc,), sentinel, idx_dt) \
            .at[local_root].min(m)
        new = jnp.where(core, root_min[local_root], labels).astype(idx_dt)
        changed_local = jnp.any(new != labels)
        changed = jax.lax.psum(changed_local.astype(jnp.int32), axis) > 0
        return new, changed, r + 1

    # psum-derived init: INVARIANT vma, matching the body's psum output
    changed0 = jax.lax.psum(jnp.int32(1), axis) > 0
    labels, _, rounds = jax.lax.while_loop(
        cond, body, (labels0, changed0, jnp.int32(0)))

    # --- border points -------------------------------------------------------
    all_labels = jnp.concatenate([labels, halo_labels(labels)])
    border = min_core_label_on(ctx.bvh_all, pts, eps_f, all_labels, all_core,
                               ~core, sentinel)
    final = jnp.where(core, labels,
                      jnp.where(border < sentinel, border, NOISE))
    final = jnp.where(final == sentinel, NOISE, final)
    return final.astype(idx_dt), core, rounds


@functools.partial(_maybe_jit,
                   static_argnames=("min_pts", "halo_cap", "axis", "mesh_ref",
                                    "max_rounds", "index_dtype"))
def _dbscan_sharded(points, eps, min_pts, halo_cap, axis, mesh_ref, max_rounds,
                    index_dtype):
    mesh = mesh_ref.mesh
    n_shards = mesh.shape[axis]

    def local_fn(pts):
        pts = pts[0]                                  # drop leading shard dim
        ctx = shard_context(pts, eps, halo_cap, axis, n_shards,
                            index_dtype=index_dtype)
        labels, core, rounds = dbscan_local_shard(
            pts, eps, min_pts, ctx, axis=axis, max_rounds=max_rounds)
        return (labels[None], core[None], rounds[None],
                ctx.exchange.overflow[None])

    spec_in = P(axis, None)
    # check_rep=False: the body contains while_loops (union fixpoints), for
    # which shard_map has no replication rule on some JAX versions.
    labels, core, rounds, ovf = shard_map(
        local_fn, mesh=mesh, in_specs=(spec_in,),
        out_specs=(P(axis), P(axis), P(axis), P(axis)),
        check_rep=False,
    )(points.reshape(n_shards, -1, points.shape[-1]))
    return (labels.reshape(-1), core.reshape(-1), jnp.max(rounds),
            jnp.any(ovf))


def dbscan_distributed(points: jax.Array, eps, min_pts: int, *, mesh: Mesh,
                       axis: str = "data", halo_cap: int = 512,
                       max_rounds: int = 64, index_dtype=jnp.int32,
                       tracer=None) -> DistDbscanResult:
    """points: (n_total, d), n_total divisible by the axis size, pre-sorted
    by x (``slab_partition``) so shard slabs are contiguous. ``index_dtype``
    sets the global-label dtype — int64 (under x64) once ``n_total`` can
    exceed 2^31.

    ``tracer`` (a ``repro.obs.SpanTracer``) wraps the fused
    exchange + core-test + union-fixpoint launch in one fenced span and
    records the merge round count / halo overflow after the fence."""
    idx_dt = _canon_index_dtype(index_dtype)
    if tracer is None:
        labels, core, rounds, ovf = _dbscan_sharded(
            points, eps, min_pts, halo_cap, axis, _mesh_ref(mesh), max_rounds,
            idx_dt)
        return DistDbscanResult(labels=labels, core_mask=core, rounds=rounds,
                                halo_overflow=ovf)
    with tracer.span("dbscan_distributed", n=int(points.shape[0]),
                     shards=int(mesh.shape[axis]), min_pts=int(min_pts)) as sp:
        labels, core, rounds, ovf = sp.fence(_dbscan_sharded(
            points, eps, min_pts, halo_cap, axis, _mesh_ref(mesh), max_rounds,
            idx_dt))
    tracer.counter("dbscan_rounds", rounds=int(rounds),
                   halo_overflow=int(ovf))
    return DistDbscanResult(labels=labels, core_mask=core, rounds=rounds,
                            halo_overflow=ovf)
