"""repro.core — the paper's contribution: ArborX-style geometric search +
DBSCAN clustering, reimplemented for JAX/TPU.

Faithful tier (GPU-paper semantics, validated against the numpy oracle):
  morton, bvh (LBVH + ropes), query (the UNIFIED ENGINE, §4.1: predicate
  constructors within/intersects_box/nearest/ray, stackless/stack/pair
  backends, fused callbacks with early exit, two-pass CSR + buffered
  single-pass output protocols, Morton query sorting), union_find, and
  its thin clients: dbscan (graph-CC, FDBSCAN, FDBSCAN-pair,
  FDBSCAN-DenseBox), knn, emst (Boruvka Euclidean MST), correlation
  (2-pt pair counts), interpolate (MLS), raycast — the full ArborX §3.2
  functionality surface. ``traversal`` keeps the pre-engine entry points
  as compatibility shims.

TPU-native tier (the production path):
  cell_grid + fdbscan_grid (tiled ε-stencil DBSCAN on the MXU, backed by
  repro.kernels.pairwise), distributed (shard_map multi-device DBSCAN).
"""
from repro.core.bvh import Bvh, build_bvh, build_bvh_objects, SENTINEL
from repro.core.cell_grid import CellGrid, build_cell_grid, cell_box
from repro.core.dbscan import (
    NOISE,
    DbscanResult,
    count_neighbors,
    dbscan_graph_cc,
    fdbscan,
    fdbscan_densebox,
    fdbscan_pair,
    min_core_label_on,
    union_rounds,
)
from repro.core.geometry import Aabb, aabb_of_points
from repro.core.morton import morton32, morton64, normalize_points
from repro.core.query import (
    BufferedCsr,
    DeviceCsr,
    IntersectsBox,
    Nearest,
    NearestResult,
    Ray,
    RayResult,
    Within,
    intersects_box,
    nearest,
    node_reduce,
    query,
    query_count,
    query_csr,
    query_csr_buffered,
    query_csr_device,
    query_fixed,
    ray,
    within,
)
from repro.core.traversal import (
    pair_traverse_sphere,
    traverse_sphere_stack,
    traverse_sphere_stackless,
)
from repro.core.knn import KnnResult, knn
from repro.core.emst import EmstResult, emst
from repro.core.correlation import pair_count_histogram, two_point_correlation
from repro.core.interpolate import mls_interpolate
from repro.core.raycast import RayHits, raycast, raycast_all
from repro.core import union_find

__all__ = [
    "Bvh", "build_bvh", "build_bvh_objects", "SENTINEL",
    "CellGrid", "build_cell_grid", "cell_box",
    "NOISE", "DbscanResult", "count_neighbors",
    "min_core_label_on", "union_rounds",
    "dbscan_graph_cc", "fdbscan", "fdbscan_densebox", "fdbscan_pair",
    "Aabb", "aabb_of_points",
    "morton32", "morton64", "normalize_points",
    "Within", "IntersectsBox", "Nearest", "Ray",
    "NearestResult", "RayResult", "DeviceCsr", "BufferedCsr",
    "within", "intersects_box", "nearest", "ray",
    "query", "query_count", "query_csr", "query_csr_buffered",
    "query_csr_device", "query_fixed",
    "node_reduce",
    "pair_traverse_sphere", "traverse_sphere_stack", "traverse_sphere_stackless",
    "KnnResult", "knn", "EmstResult", "emst",
    "pair_count_histogram", "two_point_correlation",
    "mls_interpolate", "RayHits", "raycast", "raycast_all",
    "union_find",
]
