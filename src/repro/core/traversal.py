"""BVH traversals (paper §4.1–4.2) in pure JAX.

Three faithful reproductions of ArborX's traversal machinery:

* **Stackless rope traversal** (§4.2.1, Torres et al. 2009): each query walks
  ``node -> left_child`` on hit and ``node -> rope`` on miss/leaf, with no
  per-query stack. On GPU this raises occupancy; here it means the vmapped
  while-loop carries a single int32 of traversal state per query.
* **Stack traversal** — the pre-(4) baseline from the Fig. 4 timeline, kept
  for the benchmark ladder. Carries a fixed 96-deep stack per query.
* **Pair traversal** (§4.2.3): query k starts at ``rope[leaf_k]`` instead of
  the root, so it visits exactly the leaves *after* k in Morton order —
  each unordered pair is processed once.

Callbacks (§4.1.1) are JAX closures ``leaf_fn(carry, obj_idx) -> (carry,
done)`` fused into the traversal loop; ``done=True`` reproduces the
early-termination interface (§4.1.2, ``CallbackTreeTraversalControl``).

All functions are jit/vmap-compatible; queries are vectorized with ``vmap``
(the analogue of one GPU thread per query, pre-sorted by the BVH's own Morton
order to reduce divergence, as ArborX does).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.bvh import Bvh, SENTINEL
from repro.core.geometry import point_aabb_dist2

__all__ = [
    "traverse_sphere_stackless",
    "traverse_sphere_stack",
    "pair_traverse_sphere",
]

_STACK_DEPTH = 96  # >= max tree depth: 64 code bits + 32 index tie-break bits


def _sphere_hit(bvh: Bvh, node: jax.Array, center: jax.Array, eps2: jax.Array) -> jax.Array:
    return point_aabb_dist2(center, bvh.node_lo[node], bvh.node_hi[node]) <= eps2


def traverse_sphere_stackless(
    bvh: Bvh,
    centers: jax.Array,            # (q, d) query sphere centers
    eps: jax.Array,
    leaf_fn: Callable,             # (carry, original_point_idx, sorted_idx) -> (carry, done)
    carry_init,                    # pytree, broadcast per query
    start_nodes: jax.Array | None = None,  # (q,) node ids; default root
):
    """Rope-based stackless traversal, vmapped over queries.

    ``eps`` may be a traced scalar — including one batched by an outer
    ``vmap`` (per-query radii, e.g. spherical-overdensity searches where
    every halo probes its own R_Δ candidate; see ``halos/so_mass.py``)."""
    n = bvh.num_leaves
    eps2 = jnp.asarray(eps, centers.dtype) ** 2
    root = jnp.int32(0)  # internal node 0 is the root (n >= 2)

    def one_query(center, start, carry0):
        def cond(state):
            node, _, done = state
            return (node != SENTINEL) & ~done

        def body(state):
            node, carry, done = state
            is_leaf = node >= n - 1
            sorted_idx = node - (n - 1)
            # Leaf: run callback (fused, §4.1.1), continue at rope.
            carry_leaf, done_leaf = leaf_fn(carry, bvh.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)], sorted_idx)
            next_leaf = bvh.rope[node]

            # Internal: descend on hit, rope on miss.
            hit = _sphere_hit(bvh, node, center, eps2)
            node_c = jnp.clip(node, 0, n - 2)
            next_internal = jnp.where(hit, bvh.left_child[node_c], bvh.rope[node])

            carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)
            done = jnp.where(is_leaf, done | done_leaf, done)
            node = jnp.where(is_leaf, next_leaf, next_internal)
            return node, carry, done

        _, carry, _ = jax.lax.while_loop(cond, body, (start, carry0, jnp.bool_(False)))
        return carry

    if start_nodes is None:
        start_nodes = jnp.full((centers.shape[0],), root, jnp.int32)
    carries = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (centers.shape[0],) + jnp.shape(x)), carry_init
    )
    return jax.vmap(one_query)(centers, start_nodes, carries)


def traverse_sphere_stack(
    bvh: Bvh,
    centers: jax.Array,
    eps: jax.Array,
    leaf_fn: Callable,
    carry_init,
):
    """Classic stack-based traversal (the Fig. 4 pre-stackless baseline)."""
    n = bvh.num_leaves
    eps2 = jnp.asarray(eps, centers.dtype) ** 2

    def one_query(center, carry0):
        stack0 = jnp.full((_STACK_DEPTH,), SENTINEL, jnp.int32).at[0].set(0)

        def cond(state):
            sp, _, _, done = state
            return (sp > 0) & ~done

        def body(state):
            sp, stack, carry, done = state
            node = stack[sp - 1]
            sp = sp - 1
            is_leaf = node >= n - 1
            sorted_idx = node - (n - 1)

            carry_leaf, done_leaf = leaf_fn(carry, bvh.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)], sorted_idx)

            hit = _sphere_hit(bvh, node, center, eps2) & ~is_leaf
            node_c = jnp.clip(node, 0, n - 2)
            # Push right then left so left pops first (matches rope order).
            stack = stack.at[sp].set(jnp.where(hit, bvh.right_child[node_c], stack[sp]))
            sp_r = sp + hit.astype(jnp.int32)
            stack = stack.at[sp_r].set(jnp.where(hit, bvh.left_child[node_c], stack[sp_r]))
            sp = sp_r + hit.astype(jnp.int32)

            carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)
            done = done | (is_leaf & done_leaf)
            return sp, stack, carry, done

        _, _, carry, _ = jax.lax.while_loop(cond, body, (jnp.int32(1), stack0, carry0, jnp.bool_(False)))
        return carry

    carries = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (centers.shape[0],) + jnp.shape(x)), carry_init
    )
    return jax.vmap(one_query)(centers, carries)


def pair_traverse_sphere(
    bvh: Bvh,
    points: jax.Array,             # (n, d) ORIGINAL point array the BVH indexes
    eps: jax.Array,
    leaf_fn: Callable,             # (carry, i_orig, j_orig) -> (carry, done)
    carry_init,
):
    """Pair traversal (§4.2.3): one query per point, starting at its own leaf's
    rope, so only pairs (k, m) with k < m in Morton order are visited.

    ``leaf_fn`` receives the ORIGINAL indices of both endpoints; distance
    filtering is the callback's job (as in ArborX, where the predicate check
    happens against leaf AABBs and exact tests live in the callback)."""
    n = bvh.num_leaves
    sorted_ids = jnp.arange(n, dtype=jnp.int32)
    leaf_nodes = sorted_ids + (n - 1)
    starts = bvh.rope[leaf_nodes]
    centers = points[bvh.leaf_perm]  # query k = sorted point k

    def wrapped_leaf_fn(query_orig_idx):
        def fn(carry, obj_orig_idx, _sorted_idx):
            return leaf_fn(carry, query_orig_idx, obj_orig_idx)
        return fn

    def one_query(center, start, i_orig, carry0):
        eps2 = jnp.asarray(eps, centers.dtype) ** 2

        def cond(state):
            node, _, done = state
            return (node != SENTINEL) & ~done

        def body(state):
            node, carry, done = state
            is_leaf = node >= n - 1
            sorted_idx = node - (n - 1)
            carry_leaf, done_leaf = leaf_fn(
                carry, i_orig, bvh.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)]
            )
            hit = _sphere_hit(bvh, node, center, eps2)
            node_c = jnp.clip(node, 0, n - 2)
            next_internal = jnp.where(hit, bvh.left_child[node_c], bvh.rope[node])
            carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)
            done = jnp.where(is_leaf, done | done_leaf, done)
            node = jnp.where(is_leaf, bvh.rope[node], next_internal)
            return node, carry, done

        _, carry, _ = jax.lax.while_loop(cond, body, (start, carry0, jnp.bool_(False)))
        return carry

    carries = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n,) + jnp.shape(x)), carry_init
    )
    return jax.vmap(one_query)(centers, starts, bvh.leaf_perm, carries)
