"""Legacy traversal entry points — thin shims over the unified query
engine in ``core/query.py``.

The engine (paper §4.1) owns every BVH walk in this repo: predicate
constructors (``within`` / ``intersects_box`` / ``nearest`` / ``ray``),
the ``stackless`` / ``stack`` / ``pair`` backends, fused callbacks with
early exit, CSR output protocols, and Morton query sorting. New code
should call ``repro.core.query.query`` (or the protocol helpers
``query_count`` / ``query_csr`` / ``query_csr_buffered``) directly;
these three functions keep the original pre-engine signatures alive for
existing callers and tests.

Shim contract (unchanged from the original module): ``leaf_fn(carry,
original_point_idx, sorted_idx) -> (carry, done)`` runs fused on EVERY
reached leaf (exact filtering is the callback's job — the engine's
predicate-gated callback protocol is the new-style alternative), ``eps``
may be a traced scalar (including one batched by an outer ``vmap`` for
per-query radii), and results are bit-identical to the pre-engine
implementations: the engine's generic cores are the very same loops,
with the node test made carry-aware.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.bvh import Bvh
from repro.core.geometry import point_aabb_dist2
from repro.core.query import traverse

__all__ = [
    "traverse_sphere_stackless",
    "traverse_sphere_stack",
    "pair_traverse_sphere",
]


def _sphere_qdata(bvh: Bvh, centers, eps):
    eps_q = jnp.broadcast_to(jnp.asarray(eps, centers.dtype),
                             (centers.shape[0],))
    return (centers, eps_q ** 2)


def _sphere_node_fn(bvh: Bvh):
    def node_fn(q, carry, node):
        center, eps2 = q
        return point_aabb_dist2(center, bvh.node_lo[node],
                                bvh.node_hi[node]) <= eps2
    return node_fn


def _ungated(leaf_fn: Callable):
    def fn(q, carry, obj, sorted_idx):
        return leaf_fn(carry, obj, sorted_idx)
    return fn


def traverse_sphere_stackless(
    bvh: Bvh,
    centers: jax.Array,            # (q, d) query sphere centers
    eps,
    leaf_fn: Callable,             # (carry, original_point_idx, sorted_idx) -> (carry, done)
    carry_init,                    # pytree, broadcast per query
    start_nodes: jax.Array | None = None,  # (q,) node ids; default root
):
    """Rope-based stackless traversal (§4.2.1), vmapped over queries."""
    return traverse(bvh, _sphere_qdata(bvh, centers, eps),
                    _sphere_node_fn(bvh), _ungated(leaf_fn), carry_init,
                    backend="stackless", start_nodes=start_nodes)


def traverse_sphere_stack(
    bvh: Bvh,
    centers: jax.Array,
    eps,
    leaf_fn: Callable,
    carry_init,
):
    """Classic stack-based traversal (the Fig. 4 pre-stackless baseline)."""
    return traverse(bvh, _sphere_qdata(bvh, centers, eps),
                    _sphere_node_fn(bvh), _ungated(leaf_fn), carry_init,
                    backend="stack")


def pair_traverse_sphere(
    bvh: Bvh,
    points: jax.Array,             # (n, d) ORIGINAL point array the BVH indexes
    eps,
    leaf_fn: Callable,             # (carry, i_orig, j_orig) -> (carry, done)
    carry_init,
):
    """Pair traversal (§4.2.3): one query per point, starting at its own
    leaf's rope, so only pairs (k, m) with k < m in Morton order are
    visited. ``leaf_fn`` receives the ORIGINAL indices of both endpoints;
    distance filtering is the callback's job. Carries are returned in
    SORTED query order (row k belongs to ``bvh.leaf_perm[k]``)."""
    n = bvh.num_leaves
    centers = points[bvh.leaf_perm]
    starts = bvh.rope[jnp.arange(n, dtype=jnp.int32) + (n - 1)]
    qdata = ((bvh.leaf_perm,) + _sphere_qdata(bvh, centers, eps))

    def node_fn(q, carry, node):
        _, center, eps2 = q
        return point_aabb_dist2(center, bvh.node_lo[node],
                                bvh.node_hi[node]) <= eps2

    def fn(q, carry, obj, sorted_idx):
        return leaf_fn(carry, q[0], obj)

    return traverse(bvh, qdata, node_fn, fn, carry_init,
                    backend="stackless", start_nodes=starts)
