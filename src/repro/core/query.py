"""The unified query engine (paper §4.1): one ``query(index, predicates,
callback)`` entry point behind every geometric-search workload.

ArborX's central API story is that all workloads — neighbor counting,
DBSCAN's union passes, kNN, ray casting, interpolation support, halo
analysis — converged on a SINGLE dispatcher with

* **predicates** describing what each query looks for
  (``within(centers, eps)`` spheres with scalar or per-query radii,
  ``intersects_box`` AABB overlap, ``nearest(centers, k)``,
  ``ray(origins, directions)``),
* **fused callbacks** (§4.1.1) executed per predicate-object intersection
  inside the traversal loop, with early exit (§4.1.2,
  ``CallbackTreeTraversalControl``) when the callback reports done,
* **output protocols** on top of the callback machinery: a DEVICE-RESIDENT
  scan-then-scatter CSR (``query_csr_device`` — count, on-device exclusive
  scan, resumable chunked scatter at per-query offsets; jit-traceable, no
  host sync, no dense ``(q, max_count)`` staging), its dynamic-shape host
  convenience ``query_csr``, and a single-pass fixed-capacity variant with
  overflow detection and doubling retry (``query_csr_buffered``, the §4.1
  buffer optimization, retry count observable),
* **traversal backends** (``stackless`` rope / ``stack`` / ``pallas``
  wavefront kernel / ``pair``) selectable per call, and engine-level
  Morton **query sorting** (§4.2.2) so every client inherits
  traversal-coherence improvements at once.

Clients (``knn``, ``raycast``, ``dbscan``, ``correlation``,
``interpolate``, ``emst``, ``halos/*``) are thin wrappers over this
module; the Pallas wavefront-traversal kernel
(``kernels/wavefront.py``) IS one more backend here — ``backend=
"pallas"`` — instead of N bespoke loops: a block of Morton-sorted
queries per grid step advances the rope traversal in lockstep with the
callback fused as the epilogue, and every protocol (counts, fixed
buffers, device CSR) rides it unchanged.

Layering:

* generic single-query traversal cores (``_one_stackless`` /
  ``_one_stack`` — carry-dependent node tests, fused leaf callbacks),
* ``traverse`` / ``traverse_nearest_stack`` — vmapped generic drivers
  (also the substrate for ``core.traversal``'s compatibility shims and
  EMST's component-filtered nearest search),
* ``query`` + ``query_count`` / ``query_fixed`` / ``query_csr`` /
  ``query_csr_buffered`` — the predicate dispatcher and output protocols,
* ``node_reduce`` — generic bottom-up per-node tree reduction (the same
  fixpoint the AABB build uses), for per-node metadata like EMST's
  component intervals.

Callback contract (spatial predicates): ``callback(carry, query_idx,
obj_idx, d2) -> (carry, done)`` is invoked only when the leaf's bounding
volume satisfies the predicate (for point leaves that IS the exact test);
``d2`` is the squared distance from the query geometry to the leaf volume.
``query_idx`` is the row in the predicate arrays (original order even
under ``sort_queries``), ``obj_idx`` the original object index. NOTE:
``nearest`` callbacks differ in the last argument — they receive the
EUCLIDEAN distance (the quantity the k results are ranked and returned
by), not its square.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bvh import Bvh, SENTINEL
from repro.core.geometry import aabb_aabb_dist2, point_aabb_dist2
from repro.core.morton import morton32, normalize_points, sort_by_morton32
from repro.obs.stats import TraversalStats

__all__ = [
    "Within", "IntersectsBox", "Nearest", "Ray",
    "within", "intersects_box", "nearest", "ray",
    "NearestResult", "RayResult", "DeviceCsr", "BufferedCsr",
    "query", "query_count", "query_fixed", "query_csr", "query_csr_device",
    "query_csr_buffered",
    "traverse", "traverse_nearest_stack", "node_reduce",
    "query_sort_permutation",
]

_STACK_DEPTH = 96  # >= max tree depth: 64 code bits + 32 index tie-break bits


# ---------------------------------------------------------------------------
# Predicates
# ---------------------------------------------------------------------------

class Within(NamedTuple):
    """ε-sphere predicates: all objects within ``radii`` of ``centers``."""
    centers: jax.Array   # (q, d)
    radii: jax.Array     # (q,) — per-query radii (scalar eps broadcast)


class IntersectsBox(NamedTuple):
    """AABB-overlap predicates: all objects intersecting [lo, hi]."""
    lo: jax.Array        # (q, d)
    hi: jax.Array        # (q, d)


class Nearest(NamedTuple):
    """k-nearest predicates. ``k`` is static (python int)."""
    centers: jax.Array   # (q, d)
    k: int


class Ray(NamedTuple):
    """Nearest-hit ray predicates (slab method vs leaf volumes)."""
    origins: jax.Array     # (q, d)
    directions: jax.Array  # (q, d)


def within(centers: jax.Array, radii) -> Within:
    """Sphere predicate; ``radii`` is a scalar eps or a (q,) per-query
    vector (e.g. spherical-overdensity searches, ``halos/so_mass.py``)."""
    r = jnp.broadcast_to(jnp.asarray(radii, centers.dtype), (centers.shape[0],))
    return Within(centers=centers, radii=r)


def intersects_box(lo: jax.Array, hi: jax.Array) -> IntersectsBox:
    return IntersectsBox(lo=lo, hi=hi)


def nearest(centers: jax.Array, k: int) -> Nearest:
    return Nearest(centers=centers, k=int(k))


def ray(origins: jax.Array, directions: jax.Array) -> Ray:
    return Ray(origins=origins, directions=directions)


class NearestResult(NamedTuple):
    indices: jax.Array    # (q, k) int32, sorted by distance (-1 = unfilled)
    distances: jax.Array  # (q, k) f32 euclidean


class RayResult(NamedTuple):
    index: jax.Array   # (q,) int32 — original object index (-1 = miss)
    t: jax.Array       # (q,) f32 — entry parameter along the ray


class DeviceCsr(NamedTuple):
    """Device-resident CSR output. ``indices`` is bound-sized (``capacity``);
    ``total`` is the true hit count (a device scalar — may exceed capacity,
    in which case ``overflowed`` is set and surplus hits were dropped).
    ``offsets``/``total`` carry the caller's ``index_dtype`` (int32 by
    default; pass int64 under x64 when total hits can exceed 2^31 — the
    exascale configuration the scale-safety analyzer proves out)."""
    offsets: jax.Array     # (q+1,) index_dtype exclusive-scan row starts
    indices: jax.Array     # (capacity,) int32, -1 padded past ``total``
    total: jax.Array       # () index_dtype
    overflowed: jax.Array  # () bool


def _canon_index_dtype(index_dtype):
    """Validate an offsets dtype. Requesting int64 with x64 disabled is a
    hard error: JAX would silently stage int32 and the cumsum could wrap
    past 2^31 hits (staticcheck rule W1)."""
    dt = jnp.dtype(index_dtype)
    if dt not in (jnp.dtype(jnp.int32), jnp.dtype(jnp.int64)):
        raise ValueError(f"index_dtype must be int32 or int64, got {dt}")
    if dt == jnp.dtype(jnp.int64) and not jax.config.jax_enable_x64:
        raise ValueError(
            "index_dtype=int64 requires x64 mode "
            "(jax.experimental.enable_x64() or jax_enable_x64=True); "
            "without it JAX silently truncates to int32 and CSR offsets "
            "overflow once total hits exceed 2^31")
    return dt


class BufferedCsr(NamedTuple):
    """Single-pass buffered CSR with observable retry behaviour."""
    offsets: jax.Array   # (q+1,) int32
    indices: jax.Array   # (total,) int32
    attempts: int        # host int — passes taken (1 = zero-retry fast path)
    overflowed: bool     # host bool — whether ANY attempt overflowed


# ---------------------------------------------------------------------------
# Generic traversal cores (single query; carry-dependent node tests)
# ---------------------------------------------------------------------------

def _one_stackless(bvh: Bvh, q, node_fn, leaf_fn, carry0, start):
    """Rope-based stackless walk (§4.2.1): ``left_child`` on hit, ``rope``
    on miss/leaf; a single int32 of traversal state per query."""
    n = bvh.num_leaves

    def cond(state):
        node, _, done = state
        return (node != SENTINEL) & ~done

    def body(state):
        node, carry, done = state
        is_leaf = node >= n - 1
        sorted_idx = node - (n - 1)
        carry_leaf, done_leaf = leaf_fn(
            q, carry, bvh.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)], sorted_idx)
        next_leaf = bvh.rope[node]

        hit = node_fn(q, carry, node)
        node_c = jnp.clip(node, 0, n - 2)
        next_internal = jnp.where(hit, bvh.left_child[node_c], bvh.rope[node])

        carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)
        done = jnp.where(is_leaf, done | done_leaf, done)
        node = jnp.where(is_leaf, next_leaf, next_internal)
        return node, carry, done

    _, carry, _ = jax.lax.while_loop(cond, body, (start, carry0, jnp.bool_(False)))
    return carry


def _one_stack(bvh: Bvh, q, node_fn, leaf_fn, carry0):
    """Classic stack-based walk (the Fig. 4 pre-stackless baseline)."""
    n = bvh.num_leaves
    stack0 = jnp.full((_STACK_DEPTH,), SENTINEL, jnp.int32).at[0].set(0)

    def cond(state):
        sp, _, _, done = state
        return (sp > 0) & ~done

    def body(state):
        sp, stack, carry, done = state
        node = stack[sp - 1]
        sp = sp - 1
        is_leaf = node >= n - 1
        sorted_idx = node - (n - 1)

        carry_leaf, done_leaf = leaf_fn(
            q, carry, bvh.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)], sorted_idx)

        hit = node_fn(q, carry, node) & ~is_leaf
        node_c = jnp.clip(node, 0, n - 2)
        # Push right then left so left pops first (matches rope order).
        stack = stack.at[sp].set(jnp.where(hit, bvh.right_child[node_c], stack[sp]))
        sp_r = sp + hit.astype(jnp.int32)
        stack = stack.at[sp_r].set(jnp.where(hit, bvh.left_child[node_c], stack[sp_r]))
        sp = sp_r + hit.astype(jnp.int32)

        carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)
        done = done | (is_leaf & done_leaf)
        return sp, stack, carry, done

    _, _, carry, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(1), stack0, carry0, jnp.bool_(False)))
    return carry


# --- stats-instrumented twins of the traversal cores ------------------------
#
# The ``with_stats=`` paths below are SEPARATE functions, not flags inside
# ``_one_stackless``/``_one_stack``: the stats-off path must stage the exact
# jaxpr it staged before the obs layer existed (machine-checked by the
# ``stats_path_identity`` audit in ``repro.staticcheck``), so the original
# cores stay untouched and the instrumented twins pay for their counters only
# when asked for.

def _node_depths(bvh: Bvh) -> jax.Array:
    """Per-node tree depth (root = 0), propagated top-down one level per
    iteration; ``_STACK_DEPTH`` iterations bound any tree this engine can
    traverse. Traced once per stats-on query batch (outside the vmap)."""
    n = bvh.num_leaves
    ids = jnp.arange(max(n - 1, 0), dtype=jnp.int32)

    def body(_, depth):
        d = depth[ids] + 1
        depth = depth.at[bvh.left_child].set(d)
        depth = depth.at[bvh.right_child].set(d)
        return depth

    depth0 = jnp.zeros((2 * n - 1,), jnp.int32)
    return jax.lax.fori_loop(0, _STACK_DEPTH, body, depth0)


def _one_stackless_stats(bvh: Bvh, q, node_fn, leaf_fn, carry0, start, depths):
    """``_one_stackless`` with traversal counters threaded through the loop
    carry. Returns ``(carry, (nodes, aabb_tests, leaf_tests, max_depth,
    early_exit))`` — all device scalars."""
    n = bvh.num_leaves

    def cond(state):
        node, _, done = state[0], state[1], state[2]
        return (node != SENTINEL) & ~done

    def body(state):
        node, carry, done, nodes, aabb, leaf, maxd = state
        is_leaf = node >= n - 1
        sorted_idx = node - (n - 1)
        carry_leaf, done_leaf = leaf_fn(
            q, carry, bvh.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)], sorted_idx)
        next_leaf = bvh.rope[node]

        hit = node_fn(q, carry, node)
        node_c = jnp.clip(node, 0, n - 2)
        next_internal = jnp.where(hit, bvh.left_child[node_c], bvh.rope[node])

        nodes = nodes + 1
        aabb = aabb + (~is_leaf).astype(jnp.int32)
        leaf = leaf + is_leaf.astype(jnp.int32)
        maxd = jnp.maximum(maxd, depths[node])

        carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)
        done = jnp.where(is_leaf, done | done_leaf, done)
        node = jnp.where(is_leaf, next_leaf, next_internal)
        return node, carry, done, nodes, aabb, leaf, maxd

    z = jnp.int32(0)
    _, carry, done, nodes, aabb, leaf, maxd = jax.lax.while_loop(
        cond, body, (start, carry0, jnp.bool_(False), z, z, z, z))
    return carry, (nodes, aabb, leaf, maxd, done)


def _one_stack_stats(bvh: Bvh, q, node_fn, leaf_fn, carry0):
    """``_one_stack`` with counters; ``max_depth`` is the stack's high-water
    pointer (the quantity that overflows ``_STACK_DEPTH``)."""
    n = bvh.num_leaves
    stack0 = jnp.full((_STACK_DEPTH,), SENTINEL, jnp.int32).at[0].set(0)

    def cond(state):
        sp, done = state[0], state[3]
        return (sp > 0) & ~done

    def body(state):
        sp, stack, carry, done, nodes, aabb, leaf, maxsp = state
        node = stack[sp - 1]
        sp = sp - 1
        is_leaf = node >= n - 1
        sorted_idx = node - (n - 1)

        carry_leaf, done_leaf = leaf_fn(
            q, carry, bvh.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)], sorted_idx)

        hit = node_fn(q, carry, node) & ~is_leaf
        node_c = jnp.clip(node, 0, n - 2)
        stack = stack.at[sp].set(jnp.where(hit, bvh.right_child[node_c], stack[sp]))
        sp_r = sp + hit.astype(jnp.int32)
        stack = stack.at[sp_r].set(jnp.where(hit, bvh.left_child[node_c], stack[sp_r]))
        sp = sp_r + hit.astype(jnp.int32)

        nodes = nodes + 1
        aabb = aabb + (~is_leaf).astype(jnp.int32)
        leaf = leaf + is_leaf.astype(jnp.int32)
        maxsp = jnp.maximum(maxsp, sp)

        carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)
        done = done | (is_leaf & done_leaf)
        return sp, stack, carry, done, nodes, aabb, leaf, maxsp

    z = jnp.int32(0)
    _, _, carry, done, nodes, aabb, leaf, maxsp = jax.lax.while_loop(
        cond, body,
        (jnp.int32(1), stack0, carry0, jnp.bool_(False), z, z, z, jnp.int32(1)))
    return carry, (nodes, aabb, leaf, maxsp, done)


def _stats_from_raw(raw, callback_hits=None) -> TraversalStats:
    """Assemble the (q,)-shaped raw counter columns the vmapped stats cores
    return into a :class:`TraversalStats`."""
    nodes, aabb, leaf, maxd, done = raw
    if callback_hits is None:
        callback_hits = jnp.zeros_like(nodes)
    return TraversalStats(nodes_visited=nodes, aabb_tests=aabb,
                          leaf_tests=leaf, callback_hits=callback_hits,
                          early_exits=done, max_depth=maxd)


def _broadcast_carries(carry_init, q_count: int):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (q_count,) + jnp.shape(x)), carry_init)


def traverse(bvh: Bvh, qdata, node_fn: Callable, leaf_fn: Callable, carry_init,
             *, backend: str = "stackless", start_nodes: jax.Array | None = None,
             with_stats: bool = False):
    """Generic batched traversal: the substrate every protocol builds on.

    ``qdata``: pytree of per-query arrays (leading dim q); each query's
    slice is passed to the callbacks. ``node_fn(q, carry, node) -> bool``
    decides descent (may read the carry — e.g. best-so-far pruning);
    ``leaf_fn(q, carry, obj_idx, sorted_idx) -> (carry, done)`` runs fused
    on every reached leaf. ``backend``: ``stackless`` | ``stack``.

    ``with_stats=True`` routes through the instrumented twin cores and
    returns ``(carries, TraversalStats)`` — the stats stay on device and
    vmap/shard_map like any carry. ``callback_hits`` is zero here (the
    generic driver has no hit notion; the engine protocols fill it in).
    With the default ``with_stats=False`` this stages the identical jaxpr
    it did before the obs layer existed.
    """
    leaves = jax.tree.leaves(qdata)
    if not leaves:
        raise ValueError("qdata must contain at least one per-query array")
    q_count = leaves[0].shape[0]
    carries = _broadcast_carries(carry_init, q_count)

    if backend == "stackless":
        if start_nodes is None:
            start_nodes = jnp.zeros((q_count,), jnp.int32)
        if with_stats:
            depths = _node_depths(bvh)
            out, raw = jax.vmap(
                lambda q, s, c: _one_stackless_stats(
                    bvh, q, node_fn, leaf_fn, c, s, depths)
            )(qdata, start_nodes, carries)
            return out, _stats_from_raw(raw)
        return jax.vmap(
            lambda q, s, c: _one_stackless(bvh, q, node_fn, leaf_fn, c, s)
        )(qdata, start_nodes, carries)
    if backend == "stack":
        if start_nodes is not None:
            raise ValueError("start_nodes is a stackless/pair-backend feature")
        if with_stats:
            out, raw = jax.vmap(
                lambda q, c: _one_stack_stats(bvh, q, node_fn, leaf_fn, c)
            )(qdata, carries)
            return out, _stats_from_raw(raw)
        return jax.vmap(
            lambda q, c: _one_stack(bvh, q, node_fn, leaf_fn, c)
        )(qdata, carries)
    if backend == "pallas":
        raise ValueError(
            "backend='pallas' is dispatched by the engine entry points "
            "(query/query_count/query_csr_device/...), not the generic "
            "traverse driver: the wavefront kernel must rebuild its "
            "node_fn/leaf_fn closures inside the kernel, which prebuilt "
            "user closures cannot do")
    raise ValueError(f"unknown backend {backend!r} (use 'stackless' or 'stack')")


def traverse_nearest_stack(bvh: Bvh, centers: jax.Array, qdata,
                           push_fn: Callable, leaf_fn: Callable, carry_init):
    """Distance-ordered stack traversal — the nearest-search substrate
    (paper §3.2: "relies on a stack and a priority queue").

    Children are pushed far-first (near child explored first, tightening
    the pruning bound early); ``push_fn(q, carry, child, d2_child) ->
    bool`` gates each push against the carry (e.g. the current k-th best),
    ``leaf_fn(q, carry, obj_idx, d2_leaf) -> carry`` updates the candidate
    buffer. Used by the ``nearest`` predicate and EMST's component-
    filtered nearest-neighbor search.
    """
    n = bvh.num_leaves

    def one(center, q, carry0):
        stack0 = jnp.full((_STACK_DEPTH,), SENTINEL, jnp.int32).at[0].set(0)

        def cond(state):
            sp, *_ = state
            return sp > 0

        def body(state):
            sp, stack, carry = state
            node = stack[sp - 1]
            sp = sp - 1
            is_leaf = node >= n - 1

            sorted_idx = jnp.clip(node - (n - 1), 0, n - 1)
            obj = bvh.leaf_perm[sorted_idx]
            d2_leaf = point_aabb_dist2(center, bvh.node_lo[node], bvh.node_hi[node])
            carry_leaf = leaf_fn(q, carry, obj, d2_leaf)
            carry = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), carry_leaf, carry)

            node_c = jnp.clip(node, 0, n - 2)
            left = bvh.left_child[node_c]
            right = bvh.right_child[node_c]
            dl = point_aabb_dist2(center, bvh.node_lo[left], bvh.node_hi[left])
            dr = point_aabb_dist2(center, bvh.node_lo[right], bvh.node_hi[right])
            near = jnp.where(dl <= dr, left, right)
            far = jnp.where(dl <= dr, right, left)
            d_near = jnp.minimum(dl, dr)
            d_far = jnp.maximum(dl, dr)

            push_far = (~is_leaf) & push_fn(q, carry, far, d_far)
            stack = stack.at[sp].set(jnp.where(push_far, far, stack[sp]))
            sp = sp + push_far.astype(jnp.int32)
            push_near = (~is_leaf) & push_fn(q, carry, near, d_near)
            stack = stack.at[sp].set(jnp.where(push_near, near, stack[sp]))
            sp = sp + push_near.astype(jnp.int32)
            return sp, stack, carry

        _, _, carry = jax.lax.while_loop(cond, body, (jnp.int32(1), stack0, carry0))
        return carry

    carries = _broadcast_carries(carry_init, centers.shape[0])
    return jax.vmap(one)(centers, qdata, carries)


def node_reduce(bvh: Bvh, leaf_values, combine: Callable, identity):
    """Bottom-up per-node reduction over the tree (the AABB-build fixpoint,
    generalized): returns a pytree of (2n-1, ...) node values where leaf
    node ``(n-1)+k`` holds ``leaf_values[k]`` (SORTED leaf order) and each
    internal node holds ``combine(left, right)``. Used for per-node
    metadata (e.g. EMST's component intervals)."""
    n = bvh.num_leaves
    ids = jnp.arange(n - 1, dtype=jnp.int32)

    def seed(ident, lv):
        ident_rows = jnp.broadcast_to(jnp.asarray(ident), (n - 1,) + jnp.shape(ident))
        return jnp.concatenate([ident_rows, jnp.asarray(lv)])

    vals0 = jax.tree.map(seed, identity, leaf_values)
    ready0 = jnp.concatenate([jnp.zeros(n - 1, bool), jnp.ones(n, bool)])

    def cond(state):
        _, ready = state
        return ~jnp.all(ready)

    def body(state):
        vals, ready = state
        l, r = bvh.left_child, bvh.right_child
        new = combine(jax.tree.map(lambda x: x[l], vals),
                      jax.tree.map(lambda x: x[r], vals))
        ok = ready[l] & ready[r]

        def upd(v, nv):
            mask = ok.reshape(ok.shape + (1,) * (v.ndim - 1))
            return v.at[ids].set(jnp.where(mask, nv, v[ids]))

        vals = jax.tree.map(upd, vals, new)
        ready = ready.at[ids].set(ready[ids] | ok)
        return vals, ready

    vals, _ = jax.lax.while_loop(cond, body, (vals0, ready0))
    return vals


# ---------------------------------------------------------------------------
# Morton query sorting (§4.2.2)
# ---------------------------------------------------------------------------

def query_sort_permutation(bvh: Bvh, centers: jax.Array) -> jax.Array:
    """Morton-order permutation of query centers over the tree's root AABB
    (queries outside the scene clamp to the boundary bins). Sorting queries
    the same way the leaves are sorted makes consecutive queries traverse
    similar paths — ArborX's query-sorting optimization, here an
    engine-level option every client inherits."""
    unit = normalize_points(centers.astype(jnp.float32),
                            bvh.node_lo[0].astype(jnp.float32),
                            bvh.node_hi[0].astype(jnp.float32))
    return sort_by_morton32(morton32(unit)).astype(jnp.int32)


def _apply_sort(perm, tree_):
    return jax.tree.map(lambda x: jnp.take(x, perm, axis=0), tree_)


def _invert_perm(perm: jax.Array) -> jax.Array:
    return jnp.zeros_like(perm).at[perm].set(
        jnp.arange(perm.shape[0], dtype=perm.dtype))


# ---------------------------------------------------------------------------
# The engine: predicate dispatch + fused-callback protocol
# ---------------------------------------------------------------------------

def _pred_geom(pred):
    """Per-query geometry arrays a spatial predicate contributes to qdata."""
    if isinstance(pred, Within):
        return (pred.centers, pred.radii.astype(pred.centers.dtype) ** 2)
    if isinstance(pred, IntersectsBox):
        return (pred.lo, pred.hi)
    if isinstance(pred, Ray):
        return (pred.origins, pred.directions)
    raise TypeError(f"not a spatial predicate: {type(pred).__name__}")


def _pred_fns(bvh, kind):
    """(node_fn, leaf_aux) for predicate type ``kind`` against ``bvh``.

    ``bvh`` may be the engine's :class:`Bvh` or the wavefront kernel's
    in-kernel ``TreeView`` — the Pallas backend re-invokes this factory
    INSIDE the kernel so the closures capture kernel-local array views
    rather than outer tracers (which a Pallas body must not close over).
    ``leaf_aux`` returns (d2, hit) of a leaf node's bounding volume vs the
    predicate — for point leaves this is the exact point-to-point test.
    """
    n = bvh.num_leaves

    if issubclass(kind, Within):
        def node_fn(q, carry, node):
            (_, center, r2) = q
            return point_aabb_dist2(center, bvh.node_lo[node], bvh.node_hi[node]) <= r2

        def leaf_aux(q, sorted_idx):
            (_, center, r2) = q
            leaf_node = jnp.clip(sorted_idx, 0, n - 1) + (n - 1)
            d2 = point_aabb_dist2(center, bvh.node_lo[leaf_node], bvh.node_hi[leaf_node])
            return d2, d2 <= r2

        return node_fn, leaf_aux

    if issubclass(kind, IntersectsBox):
        def node_fn(q, carry, node):
            (_, qlo, qhi) = q
            return aabb_aabb_dist2(qlo, qhi, bvh.node_lo[node], bvh.node_hi[node]) <= 0.0

        def leaf_aux(q, sorted_idx):
            (_, qlo, qhi) = q
            leaf_node = jnp.clip(sorted_idx, 0, n - 1) + (n - 1)
            d2 = aabb_aabb_dist2(qlo, qhi, bvh.node_lo[leaf_node], bvh.node_hi[leaf_node])
            return d2, d2 <= 0.0

        return node_fn, leaf_aux

    if issubclass(kind, Ray):
        # All-intersections ray mode: the predicate is "the ray's slab test
        # hits the leaf volume"; callbacks receive the ENTRY PARAMETER t in
        # the last argument slot (the quantity the nearest-hit protocol ranks
        # by), not a squared distance.
        def node_fn(q, carry, node):
            (_, origin, direction) = q
            _, hit = _ray_box(origin, _safe_inv(direction),
                              bvh.node_lo[node], bvh.node_hi[node])
            return hit

        def leaf_aux(q, sorted_idx):
            (_, origin, direction) = q
            leaf_node = jnp.clip(sorted_idx, 0, n - 1) + (n - 1)
            t, hit = _ray_box(origin, _safe_inv(direction),
                              bvh.node_lo[leaf_node], bvh.node_hi[leaf_node])
            return t, hit

        return node_fn, leaf_aux

    raise TypeError(f"not a spatial predicate: {kind.__name__}")


def _spatial_fns(bvh: Bvh, pred):
    """(qdata_geom, node_fn, leaf_aux) for a spatial predicate."""
    node_fn, leaf_aux = _pred_fns(bvh, type(pred))
    return _pred_geom(pred), node_fn, leaf_aux


def _fused_leaf_fn(leaf_aux, callback):
    """The engine's fused-callback leaf test: run the predicate's leaf_aux,
    invoke the user callback only on hits, early-exit when it says done.
    One definition shared by the vmapped cores and the wavefront kernel
    (which rebuilds it inside the kernel from a kernel-local leaf_aux)."""
    def leaf_fn(q, carry, obj, sorted_idx):
        d2, hit = leaf_aux(q, sorted_idx)
        carry2, done2 = callback(carry, q[0], obj, d2)
        carry = jax.tree.map(lambda a, b: jnp.where(hit, a, b), carry2, carry)
        return carry, hit & done2
    return leaf_fn


def _fused_leaf_fn_stats(leaf_aux, callback):
    """Stats twin of :func:`_fused_leaf_fn`: augmented carry
    (user_carry, n_hits) — the engine counts fused-callback invocations
    itself, then grafts the column into the stats record."""
    def leaf_fn(q, carry_h, obj, sorted_idx):
        carry, nh = carry_h
        d2, hit = leaf_aux(q, sorted_idx)
        carry2, done2 = callback(carry, q[0], obj, d2)
        carry = jax.tree.map(lambda a, b: jnp.where(hit, a, b), carry2, carry)
        return (carry, nh + hit.astype(jnp.int32)), hit & done2
    return leaf_fn


def _pred_centers(pred):
    if isinstance(pred, (Within, Nearest)):
        return pred.centers
    if isinstance(pred, IntersectsBox):
        return (pred.lo + pred.hi) * 0.5
    return pred.origins


def _spatial_query(bvh, pred, callback, carry_init, backend, sort_queries,
                   with_stats=False, start_nodes=None):
    geom, node_fn, leaf_aux = _spatial_fns(bvh, pred)
    q_count = jax.tree.leaves(geom)[0].shape[0]
    qidx = jnp.arange(q_count, dtype=jnp.int32)
    qdata = (qidx,) + geom

    if sort_queries:
        perm = query_sort_permutation(bvh, _pred_centers(pred))
        qdata = _apply_sort(perm, qdata)
        if start_nodes is not None:
            start_nodes = jnp.take(start_nodes, perm, axis=0)

    if backend == "pallas":
        # Wavefront kernel backend: the factory re-derives node_fn/leaf_fn
        # inside the kernel from its TreeView (a Pallas body must not
        # close over outer traced arrays). ``kind`` (a type) and the
        # engine's own callbacks are capture-safe.
        from repro.kernels.wavefront import wavefront_traverse
        kind = type(pred)
        if with_stats:
            def make_fns_s(tree):
                nf, la = _pred_fns(tree, kind)
                return nf, _fused_leaf_fn_stats(la, callback)

            (out, hits), raw = wavefront_traverse(
                bvh, qdata, make_fns_s, (carry_init, jnp.int32(0)),
                start_nodes=start_nodes, with_stats=True,
                depths=_node_depths(bvh))
            stats = _stats_from_raw(raw, callback_hits=hits)
            if sort_queries:
                inv = _invert_perm(perm)
                out = _apply_sort(inv, out)
                stats = TraversalStats(*_apply_sort(inv, tuple(stats)))
            return out, stats

        def make_fns(tree):
            nf, la = _pred_fns(tree, kind)
            return nf, _fused_leaf_fn(la, callback)

        out = wavefront_traverse(bvh, qdata, make_fns, carry_init,
                                 start_nodes=start_nodes)
        if sort_queries:
            out = _apply_sort(_invert_perm(perm), out)
        return out

    if with_stats:
        leaf_fn_s = _fused_leaf_fn_stats(leaf_aux, callback)
        (out, hits), stats = traverse(
            bvh, qdata, node_fn, leaf_fn_s, (carry_init, jnp.int32(0)),
            backend=backend, start_nodes=start_nodes, with_stats=True)
        stats = stats._replace(callback_hits=hits)
        if sort_queries:
            inv = _invert_perm(perm)
            out = _apply_sort(inv, out)
            stats = TraversalStats(*_apply_sort(inv, tuple(stats)))
        return out, stats

    leaf_fn = _fused_leaf_fn(leaf_aux, callback)
    out = traverse(bvh, qdata, node_fn, leaf_fn, carry_init, backend=backend,
                   start_nodes=start_nodes)
    if sort_queries:
        out = _apply_sort(_invert_perm(perm), out)
    return out


def _pair_query(bvh, pred, callback, carry_init, with_stats=False):
    """Pair traversal (§4.2.3): predicates must be ``within`` over the very
    points the tree indexes; query k starts at ``rope[leaf_k]`` so it
    visits exactly the leaves AFTER k in Morton order — each unordered
    pair once. Carries are returned in SORTED (Morton) query order; row k
    belongs to original point ``bvh.leaf_perm[k]`` (the index passed to
    the callback as ``query_idx``). With ``with_stats`` the stats rows are
    in the same sorted order as the carries."""
    if not isinstance(pred, Within):
        raise TypeError("backend='pair' requires a within(...) predicate over "
                        "the indexed points")
    n = bvh.num_leaves
    if pred.centers.shape[0] != n:
        raise ValueError(
            f"backend='pair' is a self-join: the predicate must cover exactly "
            f"the {n} indexed points, got {pred.centers.shape[0]} queries")
    geom, node_fn, leaf_aux = _spatial_fns(bvh, pred)
    # Query k = sorted point k; its query_idx is the ORIGINAL index leaf_perm[k].
    qdata = (bvh.leaf_perm,) + _apply_sort(bvh.leaf_perm, geom)
    starts = bvh.rope[jnp.arange(n, dtype=jnp.int32) + (n - 1)]

    if with_stats:
        leaf_fn_s = _fused_leaf_fn_stats(leaf_aux, callback)
        (out, hits), stats = traverse(
            bvh, qdata, node_fn, leaf_fn_s, (carry_init, jnp.int32(0)),
            backend="stackless", start_nodes=starts, with_stats=True)
        return out, stats._replace(callback_hits=hits)

    return traverse(bvh, qdata, node_fn, _fused_leaf_fn(leaf_aux, callback),
                    carry_init, backend="stackless", start_nodes=starts)


# --- nearest (priority-queue carry inside the engine) -----------------------

def _nearest_batched(bvh: Bvh, centers: jax.Array, k: int) -> NearestResult:
    """kNN by euclidean distance to leaf bounding volumes (== the points,
    for point leaves): ordered stack + bounded priority queue (paper §3.2).
    The candidate buffer is kept UNSORTED; the worst element is tracked by
    max() and replaced on improvement."""
    def push_fn(q, carry, child, d2):
        dists, _ = carry
        return d2 < jnp.max(dists)

    def leaf_fn(q, carry, obj, d2):
        dists, idxs = carry
        worst = jnp.argmax(dists)
        better = d2 < dists[worst]
        dists = jnp.where(better, dists.at[worst].set(d2), dists)
        idxs = jnp.where(better, idxs.at[worst].set(obj), idxs)
        return dists, idxs

    d0 = jnp.full((k,), jnp.inf, jnp.float32)
    i0 = jnp.full((k,), -1, jnp.int32)
    dists, idxs = traverse_nearest_stack(
        bvh, centers, jnp.zeros((centers.shape[0],), jnp.int8),
        push_fn, leaf_fn, (d0, i0))
    order = jnp.argsort(dists, axis=1)
    return NearestResult(indices=jnp.take_along_axis(idxs, order, axis=1),
                         distances=jnp.sqrt(jnp.take_along_axis(dists, order, axis=1)))


def _nearest_query(bvh, pred: Nearest, callback, carry_init, sort_queries):
    centers = pred.centers
    if sort_queries:
        perm = query_sort_permutation(bvh, centers)
        centers = centers[perm]
    res = _nearest_batched(bvh, centers, pred.k)
    if sort_queries:
        inv = _invert_perm(perm)
        res = NearestResult(indices=res.indices[inv], distances=res.distances[inv])
    if callback is None:
        return res

    # Callback protocol: invoked per result in ascending-distance order,
    # with the EUCLIDEAN distance (unlike spatial callbacks, which get d2).
    q_count = pred.centers.shape[0]

    def one(qidx, idxs, dists, carry0):
        def step(i, state):
            carry, done = state
            carry2, done2 = callback(carry, qidx, idxs[i], dists[i])
            valid = (idxs[i] >= 0) & ~done
            carry = jax.tree.map(lambda a, b: jnp.where(valid, a, b), carry2, carry)
            return carry, done | (valid & done2)

        carry, _ = jax.lax.fori_loop(0, pred.k, step, (carry0, jnp.bool_(False)))
        return carry

    carries = _broadcast_carries(carry_init, q_count)
    return jax.vmap(one)(jnp.arange(q_count, dtype=jnp.int32),
                         res.indices, res.distances, carries)


# --- rays (nearest-hit protocol) --------------------------------------------

def _safe_inv(direction):
    """1/direction with zero components nudged off the axis (slab method)."""
    return 1.0 / jnp.where(jnp.abs(direction) < 1e-12,
                           jnp.sign(direction) * 1e-12 + 1e-12, direction)


def _ray_box(origin, inv_dir, lo, hi):
    """Slab test. Returns (t_entry, hit) with t_entry >= 0."""
    t0 = (lo - origin) * inv_dir
    t1 = (hi - origin) * inv_dir
    tmin = jnp.max(jnp.minimum(t0, t1))
    tmax = jnp.min(jnp.maximum(t0, t1))
    hit = (tmax >= jnp.maximum(tmin, 0.0))
    return jnp.maximum(tmin, 0.0), hit


def _ray_batched(bvh: Bvh, origins: jax.Array, directions: jax.Array) -> RayResult:
    """Nearest leaf-volume hit per ray: ordered stack traversal pruning
    nodes whose entry t exceeds the current best."""
    n = bvh.num_leaves

    def one(origin, direction):
        inv = _safe_inv(direction)
        stack0 = jnp.full((_STACK_DEPTH,), SENTINEL, jnp.int32).at[0].set(0)

        def cond(state):
            return state[0] > 0

        def body(state):
            sp, stack, best_t, best_i = state
            node = stack[sp - 1]
            sp = sp - 1
            is_leaf = node >= n - 1
            t_in, hit = _ray_box(origin, inv, bvh.node_lo[node],
                                 bvh.node_hi[node])
            closer = hit & (t_in < best_t)

            sorted_idx = jnp.clip(node - (n - 1), 0, n - 1)
            orig = bvh.leaf_perm[sorted_idx]
            take = is_leaf & closer
            best_i = jnp.where(take, orig, best_i)
            best_t = jnp.where(take, t_in, best_t)

            node_c = jnp.clip(node, 0, n - 2)
            for child in (bvh.right_child[node_c], bvh.left_child[node_c]):
                tc, hc = _ray_box(origin, inv, bvh.node_lo[child],
                                  bvh.node_hi[child])
                push = (~is_leaf) & closer & hc & (tc < best_t)
                stack = stack.at[sp].set(jnp.where(push, child, stack[sp]))
                sp = sp + push.astype(jnp.int32)
            return sp, stack, best_t, best_i

        _, _, best_t, best_i = jax.lax.while_loop(
            cond, body, (jnp.int32(1), stack0, jnp.float32(jnp.inf),
                         jnp.int32(-1)))
        return best_i, best_t

    idx, t = jax.vmap(one)(origins, directions)
    return RayResult(index=idx, t=t)


def _ray_query(bvh, pred: Ray, callback, sort_queries):
    """Nearest-hit protocol (callback=None). With a callback, rays dispatch
    through the spatial path instead — the ALL-INTERSECTIONS protocol."""
    origins, directions = pred.origins, pred.directions
    if sort_queries:
        perm = query_sort_permutation(bvh, origins)
        origins, directions = origins[perm], directions[perm]
    res = _ray_batched(bvh, origins, directions)
    if sort_queries:
        inv = _invert_perm(perm)
        res = RayResult(index=res.index[inv], t=res.t[inv])
    return res


def query(bvh: Bvh, predicates, callback: Callable | None = None,
          carry_init=None, *, backend: str = "stackless",
          sort_queries: bool = False, with_stats: bool = False,
          start_nodes: jax.Array | None = None):
    """The single entry point (§4.1): dispatch ``predicates`` against the
    tree, fusing ``callback`` into the traversal.

    * ``Within`` / ``IntersectsBox`` + callback -> per-query final carries.
      ``backend``: ``stackless`` | ``stack`` | ``pallas`` (the wavefront
      kernel — a block of queries per grid step advances the rope
      traversal in lockstep; interpret mode on CPU, native on TPU) |
      ``pair`` (self-join; carries in sorted leaf order, see
      ``_pair_query``).
    * ``Nearest`` -> ``NearestResult`` (or carries, if a callback is given:
      invoked per result in ascending-distance order).
    * ``Ray`` without callback -> ``RayResult`` (nearest hit). With a
      callback, rays run the ALL-INTERSECTIONS protocol: the callback fires
      per leaf volume the ray pierces, with the entry parameter ``t`` in the
      last argument (so every output protocol — counts, fixed buffers, CSR —
      works on rays too).

    ``sort_queries=True`` Morton-sorts queries against the tree's scene
    bounds before traversal and unsorts the outputs (§4.2.2) — results are
    positionally identical, traversal is more coherent.

    ``with_stats=True`` (spatial predicates with a callback only) returns
    ``(result, TraversalStats)`` — per-query device-side traversal
    counters, see ``repro.obs.stats``. Off by default; the default path
    stages the identical jaxpr it did before the obs layer existed.

    ``start_nodes`` (stackless/pallas spatial traversals only) overrides
    the per-query traversal entry node — the cell-grid pruned variants
    start queries below the root.
    """
    if with_stats and (isinstance(predicates, Nearest)
                       or (isinstance(predicates, Ray) and callback is None)):
        raise ValueError(
            "with_stats instruments the spatial traversal cores; the "
            "nearest / nearest-hit-ray protocols run on the priority-queue "
            "substrate, which has no stats threading")
    if start_nodes is not None and (
            isinstance(predicates, Nearest)
            or (isinstance(predicates, Ray) and callback is None)
            or backend == "pair"):
        raise ValueError(
            "start_nodes applies to the spatial stackless/pallas traversals; "
            "the nearest protocols have their own ordering and the pair "
            "backend derives its own start nodes")
    if isinstance(predicates, Nearest):
        return _nearest_query(bvh, predicates, callback, carry_init, sort_queries)
    if isinstance(predicates, Ray):
        if callback is None:
            return _ray_query(bvh, predicates, None, sort_queries)
        if backend == "pair":
            raise ValueError("backend='pair' is a within() self-join")
        return _spatial_query(bvh, predicates, callback, carry_init, backend,
                              sort_queries, with_stats, start_nodes)
    if not isinstance(predicates, (Within, IntersectsBox)):
        raise TypeError(f"unknown predicate type {type(predicates).__name__}")
    if callback is None:
        raise ValueError("spatial predicates need a callback; use "
                         "query_count/query_csr for built-in output protocols")
    if backend == "pair":
        if sort_queries:
            raise ValueError("backend='pair' queries are inherently "
                             "Morton-sorted; sort_queries does not apply")
        return _pair_query(bvh, predicates, callback, carry_init, with_stats)
    return _spatial_query(bvh, predicates, callback, carry_init, backend,
                          sort_queries, with_stats, start_nodes)


# ---------------------------------------------------------------------------
# Output protocols on top of the callback machinery
# ---------------------------------------------------------------------------

def query_count(bvh: Bvh, predicates, *, stop_at: int | None = None,
                backend: str = "stackless", sort_queries: bool = False,
                with_stats: bool = False,
                start_nodes: jax.Array | None = None) -> jax.Array:
    """Per-query intersection counts. ``stop_at`` enables early termination
    (§4.1.2): counting stops (and saturates) at ``stop_at`` — DBSCAN's
    minPts core test needs no exact counts beyond it. ``with_stats=True``
    returns ``(counts, TraversalStats)``."""
    if backend == "pair":
        raise ValueError("output protocols are per-query; the pair backend's "
                         "half-counts need a callback (use query(...))")

    def cb(count, qidx, obj, d2):
        count = count + 1
        done = jnp.bool_(False) if stop_at is None else count >= stop_at
        return count, done

    return query(bvh, predicates, cb, jnp.int32(0), backend=backend,
                 sort_queries=sort_queries, with_stats=with_stats,
                 start_nodes=start_nodes)


def query_fixed(bvh: Bvh, predicates, capacity: int, *,
                backend: str = "stackless", sort_queries: bool = False):
    """Single-pass fixed-capacity output: per-query index buffers
    ``(q, capacity)`` (-1 padded; surplus hits overwrite the last slot),
    true counts ``(q,)``, and an overflow flag ``any(counts > capacity)``.
    The §4.1 buffer-optimization primitive — see ``query_csr_buffered``
    for the doubling retry loop."""
    if backend == "pair":
        raise ValueError("output protocols are per-query; the pair backend's "
                         "half-lists need a callback (use query(...))")

    def cb(carry, qidx, obj, d2):
        buf, cnt = carry
        slot = jnp.clip(cnt, 0, capacity - 1)
        buf = buf.at[slot].set(obj)
        return (buf, cnt + 1), jnp.bool_(False)

    buf0 = jnp.full((capacity,), -1, jnp.int32)
    buf, counts = query(bvh, predicates, cb, (buf0, jnp.int32(0)),
                        backend=backend, sort_queries=sort_queries)
    return buf, counts, jnp.any(counts > capacity)


def _compact_csr(buf: jax.Array, counts: jax.Array,
                 index_dtype=jnp.int32):
    """Scatter per-query buffers (q, cap) into CSR (offsets, indices)."""
    idx_dt = _canon_index_dtype(index_dtype)
    q, cap = buf.shape
    offsets = jnp.concatenate([jnp.zeros((1,), idx_dt),
                               jnp.cumsum(counts, dtype=idx_dt)])
    total = int(offsets[-1]) if q else 0
    pos = offsets[:-1, None] + jnp.arange(cap, dtype=idx_dt)[None, :]
    valid = jnp.arange(cap)[None, :] < counts[:, None]
    # invalid lanes write to a trash slot past the end
    indices = jnp.full((total + 1,), -1, jnp.int32).at[
        jnp.where(valid, pos, total)].set(buf)[:total]
    return offsets, indices


def _csr_fill(bvh: Bvh, pred, offsets: jax.Array, capacity: int, *,
              chunk: int, backend: str, sort_queries: bool) -> jax.Array:
    """Pass 2 of the device-resident protocol: RESUMABLE chunked
    scatter-fill. Each query carries its paused traversal state (one int32
    node pointer for the rope backend, (sp, stack) for the stack backend);
    per outer round every live query collects up to ``chunk`` hits, which
    are scattered straight to ``offsets[q] + slot`` in the shared
    total-size buffer. Staging memory is O(q * chunk), never
    ``(q, max_count)``, and traversal work is not repeated across rounds —
    each round resumes exactly where the last one paused. All control flow
    is ``lax.while_loop``: no host sync anywhere."""
    geom, node_fn, leaf_aux = _spatial_fns(bvh, pred)
    q_count = jax.tree.leaves(geom)[0].shape[0]
    qdata = (jnp.arange(q_count, dtype=jnp.int32),) + geom
    if sort_queries:
        perm = query_sort_permutation(bvh, _pred_centers(pred))
        qdata = _apply_sort(perm, qdata)
    n = bvh.num_leaves
    chunk = max(int(chunk), 1)
    out0 = jnp.full((capacity + 1,), -1, jnp.int32)  # last slot = trash
    if q_count == 0:
        return out0[:capacity]
    # Output segment start per traversal lane (original-order offsets).
    base = offsets[:-1][qdata[0]]

    def record(q, buf, nh, node):
        is_leaf = node >= n - 1
        sorted_idx = jnp.clip(node - (n - 1), 0, n - 1)
        _, hit = leaf_aux(q, sorted_idx)
        take = is_leaf & hit
        buf = jnp.where(
            take, buf.at[jnp.clip(nh, 0, chunk - 1)].set(
                bvh.leaf_perm[sorted_idx]), buf)
        return buf, nh + take.astype(jnp.int32), is_leaf

    if backend == "pallas":
        # Wavefront rounds: one kernel launch per chunk round advances every
        # lane up to `chunk` hits; the factory rebuilds the predicate
        # closures inside the kernel (Pallas bodies must not capture outer
        # tracers). Same resumable int32 node cursor as the rope backend.
        from repro.kernels.wavefront import wavefront_fill_round
        kind = type(pred)
        state0 = jnp.zeros((q_count,), jnp.int32)

        def live(state):
            return state != SENTINEL

        def round_all(state):
            return wavefront_fill_round(
                bvh, qdata, lambda tree: _pred_fns(tree, kind), state, chunk)
    elif backend == "stackless":
        state0 = jnp.zeros((q_count,), jnp.int32)

        def live(state):
            return state != SENTINEL

        def round_one(q, node0):
            def cond(s):
                node, _, nh = s
                return (node != SENTINEL) & (nh < chunk)

            def body(s):
                node, buf, nh = s
                buf, nh, is_leaf = record(q, buf, nh, node)
                node_c = jnp.clip(node, 0, n - 2)
                descend = node_fn(q, None, node)
                node = jnp.where(
                    is_leaf, bvh.rope[node],
                    jnp.where(descend, bvh.left_child[node_c],
                              bvh.rope[node]))
                return node, buf, nh

            node, buf, nh = jax.lax.while_loop(
                cond, body,
                (node0, jnp.full((chunk,), -1, jnp.int32), jnp.int32(0)))
            return node, buf, nh
    elif backend == "stack":
        state0 = (jnp.ones((q_count,), jnp.int32),
                  jnp.full((q_count, _STACK_DEPTH), SENTINEL,
                           jnp.int32).at[:, 0].set(0))

        def live(state):
            return state[0] > 0

        def round_one(q, st0):
            def cond(s):
                sp, _, _, nh = s
                return (sp > 0) & (nh < chunk)

            def body(s):
                sp, stack, buf, nh = s
                node = stack[sp - 1]
                sp = sp - 1
                buf, nh, is_leaf = record(q, buf, nh, node)
                descend = node_fn(q, None, node) & ~is_leaf
                node_c = jnp.clip(node, 0, n - 2)
                stack = stack.at[sp].set(
                    jnp.where(descend, bvh.right_child[node_c], stack[sp]))
                sp_r = sp + descend.astype(jnp.int32)
                stack = stack.at[sp_r].set(
                    jnp.where(descend, bvh.left_child[node_c], stack[sp_r]))
                return sp_r + descend.astype(jnp.int32), stack, buf, nh

            sp, stack, buf, nh = jax.lax.while_loop(
                cond, body, (st0[0], st0[1],
                             jnp.full((chunk,), -1, jnp.int32), jnp.int32(0)))
            return (sp, stack), buf, nh
    else:
        raise ValueError(f"unknown backend {backend!r} for the device CSR "
                         "path (use 'stackless', 'stack' or 'pallas')")

    if backend != "pallas":
        def round_all(state):
            return jax.vmap(round_one)(qdata, state)

    lane = jnp.arange(chunk, dtype=jnp.int32)[None, :]

    def cond(loop):
        state, _, _ = loop
        return jnp.any(live(state))

    def body(loop):
        state, emitted, out = loop
        state, bufs, nhs = round_all(state)
        pos = (base + emitted)[:, None] + lane
        ok = (lane < nhs[:, None]) & (pos < capacity)
        out = out.at[jnp.where(ok, pos, capacity).reshape(-1)] \
            .set(bufs.reshape(-1))
        return state, emitted + nhs, out

    _, _, out = jax.lax.while_loop(
        cond, body, (state0, jnp.zeros((q_count,), jnp.int32), out0))
    return out[:capacity]


def query_csr_device(bvh: Bvh, predicates, capacity: int, *, counts=None,
                     chunk: int = 32, backend: str = "stackless",
                     sort_queries: bool = False,
                     index_dtype=jnp.int32) -> DeviceCsr:
    """Fully DEVICE-RESIDENT scan-then-scatter CSR (the ArborX 2.0
    count-then-fill backbone, with no host round-trip): pass 1 counts per
    predicate, an on-device exclusive scan produces per-query offsets, and
    pass 2's fused traversal scatters hits directly at ``offsets[q] + slot``
    into one total-size buffer of static bound ``capacity``.

    jit-traceable end-to-end — there is NO Python-level sync of device
    values between the count and fill passes, and no dense
    ``(q, max_count)`` staging buffer (staging is O(q * chunk)). Returns
    ``DeviceCsr(offsets, indices, total, overflowed)``; hits past
    ``capacity`` are dropped and flagged. ``counts`` may be passed to reuse
    a precomputed pass 1. ``index_dtype`` sets the offsets/total dtype —
    int64 (under x64) once total hits can exceed 2^31."""
    if backend == "pair":
        raise ValueError("output protocols are per-query; the pair backend's "
                         "half-lists need a callback (use query(...))")
    idx_dt = _canon_index_dtype(index_dtype)
    capacity = max(int(capacity), 0)
    if counts is None:
        counts = query_count(bvh, predicates, backend=backend,
                             sort_queries=sort_queries)
    offsets = jnp.concatenate([jnp.zeros((1,), idx_dt),
                               jnp.cumsum(counts, dtype=idx_dt)])
    indices = _csr_fill(bvh, predicates, offsets, capacity, chunk=chunk,
                        backend=backend, sort_queries=sort_queries)
    total = offsets[-1]
    return DeviceCsr(offsets=offsets, indices=indices, total=total,
                     overflowed=total > capacity)


def query_csr(bvh: Bvh, predicates, *, capacity: int | None = None,
              chunk: int = 32, backend: str = "stackless",
              sort_queries: bool = False, index_dtype=jnp.int32) -> DeviceCsr:
    """Count-then-fill CSR output (§4.1), device-resident. With
    ``capacity`` given this IS ``query_csr_device`` (jit-traceable, zero
    host syncs). With ``capacity=None`` (the dynamic-shape convenience,
    host-side only) the exact total sizes ``indices`` — the one
    unavoidable sync for a data-dependent output shape; the count and fill
    passes themselves still never stage a dense ``(q, max_count)`` buffer.

    Returns ``DeviceCsr(offsets (q+1,), indices, total, overflowed)`` with
    per-query indices in traversal order; ``overflowed`` is always False on
    the exact-size path. Handles empty predicate sets (q == 0: offsets is
    ``[0]``, indices empty)."""
    if capacity is not None:
        return query_csr_device(bvh, predicates, capacity, chunk=chunk,
                                backend=backend, sort_queries=sort_queries,
                                index_dtype=index_dtype)
    counts = query_count(bvh, predicates, backend=backend,
                         sort_queries=sort_queries)
    exact = int(jnp.sum(counts)) if counts.shape[0] else 0
    return query_csr_device(bvh, predicates, exact, counts=counts,
                            chunk=chunk, backend=backend,
                            sort_queries=sort_queries,
                            index_dtype=index_dtype)


def query_csr_buffered(bvh: Bvh, predicates, *, capacity: int = 8,
                       max_doublings: int = 16, backend: str = "stackless",
                       sort_queries: bool = False) -> BufferedCsr:
    """Single-pass CSR with the §4.1 buffer optimization: optimistically
    fill fixed per-query buffers of ``capacity``; if ANY query overflows,
    double and retry (each retry is one pass — the common case is zero
    retries, beating the two-pass protocol by ~2x when the guess holds).
    Host-driven by construction (each retry decision is a sync). Returns
    ``BufferedCsr(offsets, indices, attempts, overflowed)`` — the retry
    count is observable, not silent: ``attempts == 1`` is the zero-retry
    fast path, ``overflowed`` reports whether any pass overflowed."""
    cap = max(int(capacity), 1)
    overflowed_any = False
    for attempt in range(1, max_doublings + 2):
        buf, counts, overflow = query_fixed(bvh, predicates, cap,
                                            backend=backend,
                                            sort_queries=sort_queries)
        if not bool(overflow):
            offsets, indices = _compact_csr(buf, counts)
            return BufferedCsr(offsets=offsets, indices=indices,
                               attempts=attempt, overflowed=overflowed_any)
        overflowed_any = True
        cap *= 2
    raise RuntimeError(f"query_csr_buffered: still overflowing at capacity {cap}")
