"""k-nearest-neighbor search (paper §3.2: the second core search type).

"The nearest search ... can terminate early while it has found the best
possible candidates. [It] is more complicated to implement, and relies on a
stack and a priority queue structures."

Thin client of the unified query engine: ``knn`` is the ``nearest(k)``
predicate dispatched through ``core.query.query`` — the ordered-stack
traversal and the bounded priority-queue carry live inside the engine
(``query._nearest_batched`` over ``traverse_nearest_stack``), shared with
EMST's component-filtered nearest search and MLS interpolation support.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax

from repro.core.bvh import Bvh
from repro.core.query import nearest, query

__all__ = ["KnnResult", "knn"]


class KnnResult(NamedTuple):
    indices: jax.Array    # (q, k) int32 — original point indices, sorted by dist
    distances: jax.Array  # (q, k) float32 — euclidean distances


@partial(jax.jit, static_argnames=("k",))
def knn(bvh: Bvh, points: jax.Array, queries: jax.Array, k: int) -> KnnResult:
    """k nearest points (by euclidean distance) for each query row.

    ``points`` is kept in the signature for backward compatibility; the
    engine reads leaf bounding volumes (== the points, for point trees)."""
    n = bvh.num_leaves
    assert k <= n, (k, n)
    res = query(bvh, nearest(queries, k))
    return KnnResult(indices=res.indices, distances=res.distances)
