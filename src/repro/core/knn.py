"""k-nearest-neighbor search (paper §3.2: the second core search type).

"The nearest search ... can terminate early while it has found the best
possible candidates. [It] is more complicated to implement, and relies on a
stack and a priority queue structures."

Faithful JAX implementation: stack-based traversal with a fixed-size
max-heap-style candidate buffer per query (the bounded priority queue);
subtrees are pruned when their AABB distance exceeds the current k-th best.
Children are pushed far-first so the near child is explored first (the
classic best-first approximation that tightens the pruning bound early).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bvh import Bvh, SENTINEL
from repro.core.geometry import point_aabb_dist2

_STACK_DEPTH = 96

__all__ = ["KnnResult", "knn"]


class KnnResult(NamedTuple):
    indices: jax.Array    # (q, k) int32 — original point indices, sorted by dist
    distances: jax.Array  # (q, k) float32 — euclidean distances


def _insert(dists, idxs, d, i):
    """Insert (d, i) into the descending-replacement candidate buffer:
    replaces the current worst if better. Buffers are kept UNSORTED; the
    worst element is tracked by max()."""
    worst = jnp.argmax(dists)
    better = d < dists[worst]
    dists = jnp.where(better, dists.at[worst].set(d), dists)
    idxs = jnp.where(better, idxs.at[worst].set(i), idxs)
    return dists, idxs


@partial(jax.jit, static_argnames=("k",))
def knn(bvh: Bvh, points: jax.Array, queries: jax.Array, k: int) -> KnnResult:
    """k nearest points (by euclidean distance) for each query row."""
    n = bvh.num_leaves
    assert k <= n, (k, n)

    def one_query(center):
        stack0 = jnp.full((_STACK_DEPTH,), SENTINEL, jnp.int32).at[0].set(0)
        d0 = jnp.full((k,), jnp.inf, jnp.float32)
        i0 = jnp.full((k,), -1, jnp.int32)

        def cond(state):
            sp, *_ = state
            return sp > 0

        def body(state):
            sp, stack, dists, idxs = state
            node = stack[sp - 1]
            sp = sp - 1
            kth = jnp.max(dists)                      # current pruning radius²
            is_leaf = node >= n - 1

            # leaf: exact distance, try to insert
            sorted_idx = jnp.clip(node - (n - 1), 0, n - 1)
            orig = bvh.leaf_perm[sorted_idx]
            d_leaf = jnp.sum((points[orig] - center) ** 2)
            new_d, new_i = _insert(dists, idxs, d_leaf, orig)
            dists = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), new_d, dists)
            idxs = jax.tree.map(lambda a, b: jnp.where(is_leaf, a, b), new_i, idxs)

            # internal: push children (far first) if their box can beat kth
            node_c = jnp.clip(node, 0, n - 2)
            left = bvh.left_child[node_c]
            right = bvh.right_child[node_c]
            dl = point_aabb_dist2(center, bvh.node_lo[left], bvh.node_hi[left])
            dr = point_aabb_dist2(center, bvh.node_lo[right], bvh.node_hi[right])
            near = jnp.where(dl <= dr, left, right)
            far = jnp.where(dl <= dr, right, left)
            d_near = jnp.minimum(dl, dr)
            d_far = jnp.maximum(dl, dr)

            push_far = (~is_leaf) & (d_far < kth)
            stack = stack.at[sp].set(jnp.where(push_far, far, stack[sp]))
            sp = sp + push_far.astype(jnp.int32)
            push_near = (~is_leaf) & (d_near < kth)
            stack = stack.at[sp].set(jnp.where(push_near, near, stack[sp]))
            sp = sp + push_near.astype(jnp.int32)
            return sp, stack, dists, idxs

        _, _, dists, idxs = jax.lax.while_loop(
            cond, body, (jnp.int32(1), stack0, d0, i0))
        order = jnp.argsort(dists)
        return KnnResult(indices=idxs[order],
                         distances=jnp.sqrt(dists[order]))

    return jax.vmap(one_query)(queries)
