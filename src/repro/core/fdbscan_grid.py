"""TPU-native FDBSCAN (DESIGN.md §2): ε-cell binning + MXU stencil kernels.

The faithful tier (``dbscan.py``) reproduces ArborX's SIMT algorithms; this
module is the *production* path on TPU. It keeps the paper's insight —
spatially sort, test only geometrically adjacent candidates, fuse the
user operation into the traversal so neighbor lists are never materialized —
but expresses it as dense tile algebra:

  1. Bin points into a regular grid of ε-sized cells with a fixed per-cell
     capacity C (slot padding at BIG). The grid replaces the BVH: cell
     adjacency (a 3^d stencil) is the TPU analogue of BVH pruning.
  2. Core-point counting = ``stencil_count`` Pallas kernel: one (C, D)×(D, C)
     MXU tile per (cell, stencil slot), counting ε-hits in the epilogue
     (callback fusion, §4.1.1/§4.1.2).
  3. Cluster construction = iterated ``stencil_min_label`` + hook/compress
     (deterministic min-label union-find, §4.3.3 / deviation 3).
  4. Border points take the min ε-reachable core label (Ester semantics).

Everything after binning is fixed-shape and jit-compatible. Binning capacity
overflow is reported via an ``overflowed`` flag (the production driver
re-bins with a larger capacity — the same contract as ArborX's documented
out-of-memory behaviour for the adjacency-graph variant, §4.3.1, but
recoverable).
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dbscan import NOISE, DbscanResult
from repro.core import union_find
from repro.kernels import ops as kops
from repro.kernels.pairwise import BIG, SENTINEL_LABEL

__all__ = ["CellBins", "GridAutoInfo", "bin_points", "stencil_neighbor_map",
           "fdbscan_grid", "fdbscan_grid_auto", "grid_dims_for"]


class CellBins(NamedTuple):
    """Slot-padded cell layout. ncells = prod(grid_dims); slot space is
    (ncells + 1, capacity) with the last cell all-padding (stencil sink)."""

    cell_pts: jax.Array        # (ncells + 1, C, D) float32, padded with BIG
    slot_of_point: jax.Array   # (n,) int32 flat slot id; overflow -> sink slot
    overflowed: jax.Array      # () bool — any point dropped by capacity

    @property
    def num_cells(self) -> int:  # static (shape-derived, jit-safe)
        return self.cell_pts.shape[0] - 1


def grid_dims_for(scene_lo, scene_hi, cell_size: float) -> tuple[int, ...]:
    """Static grid dims (host-side; scene box must be concrete)."""
    lo = np.asarray(scene_lo, np.float64)
    hi = np.asarray(scene_hi, np.float64)
    return tuple(int(max(1, math.ceil(e / cell_size))) for e in (hi - lo))


def stencil_neighbor_map(grid_dims: tuple[int, ...], reach: int = 1) -> np.ndarray:
    """(ncells, (2*reach+1)^d) int32 candidate-cell map; ncells = sink id for
    out-of-range neighbors. Host-side static table (scalar-prefetched)."""
    dims = np.asarray(grid_dims, np.int64)
    ncells = int(np.prod(dims))
    coords = np.stack(np.unravel_index(np.arange(ncells), grid_dims), axis=1)
    offs = np.stack(np.meshgrid(*([np.arange(-reach, reach + 1)] * len(grid_dims)),
                                indexing="ij"), axis=-1).reshape(-1, len(grid_dims))
    nb = coords[:, None, :] + offs[None, :, :]
    ok = ((nb >= 0) & (nb < dims[None, None, :])).all(-1)
    nb = np.clip(nb, 0, dims - 1)
    lin = np.ravel_multi_index(nb.reshape(-1, len(grid_dims)).T, grid_dims).reshape(nb.shape[:2])
    return np.where(ok, lin, ncells).astype(np.int32)


@partial(jax.jit, static_argnames=("grid_dims", "capacity"))
def bin_points(points: jax.Array, scene_lo: jax.Array, cell_size,
               grid_dims: tuple[int, ...], capacity: int) -> CellBins:
    n, d = points.shape
    dims = jnp.asarray(grid_dims, jnp.int32)
    ncells = int(np.prod(grid_dims))
    coord = jnp.floor((points - scene_lo) / cell_size).astype(jnp.int32)
    coord = jnp.clip(coord, 0, dims - 1)
    lin = coord[:, 0]
    for k in range(1, d):
        lin = lin * dims[k] + coord[:, k]

    # Rank within cell: stable sort by cell, rank = pos - run_start.
    order = jnp.argsort(lin, stable=True).astype(jnp.int32)
    lin_sorted = lin[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_head = jnp.concatenate([jnp.ones(1, bool), lin_sorted[1:] != lin_sorted[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_head, idx, 0))
    rank_sorted = idx - run_start

    ok_sorted = rank_sorted < capacity
    sink = ncells * capacity
    slot_sorted = jnp.where(ok_sorted, lin_sorted * capacity + rank_sorted, sink)
    slot = jnp.zeros(n, jnp.int32).at[order].set(slot_sorted)

    flat = jnp.full(((ncells + 1) * capacity, d), BIG, jnp.float32)
    flat = flat.at[slot].set(points.astype(jnp.float32), mode="drop")
    # Overflow points must NOT land in the sink cell as real coordinates.
    flat = flat.at[sink].set(jnp.full((d,), BIG, jnp.float32))

    return CellBins(
        cell_pts=flat.reshape(ncells + 1, capacity, d),
        slot_of_point=slot,
        overflowed=jnp.any(~ok_sorted),
    )


def _scatter_slots(values: jax.Array, fill, bins: CellBins, dtype=jnp.int32) -> jax.Array:
    """Scatter per-point values into the (ncells+1, C) slot layout."""
    ncells_p1, cap = bins.cell_pts.shape[:2]
    flat = jnp.full((ncells_p1 * cap,), fill, dtype)
    flat = flat.at[bins.slot_of_point].set(values.astype(dtype))
    sink = bins.num_cells * cap
    flat = flat.at[sink:].set(fill)  # overflow writes land in the sink; reset
    return flat.reshape(ncells_p1, cap)


@partial(jax.jit, static_argnames=("min_pts", "grid_dims", "capacity", "interpret", "max_rounds"))
def fdbscan_grid(points: jax.Array, eps, min_pts: int, *,
                 scene_lo, grid_dims: tuple[int, ...], capacity: int,
                 interpret: bool = kops.INTERPRET,
                 max_rounds: int = 64) -> tuple[DbscanResult, jax.Array]:
    """TPU-native FDBSCAN over (n, d) points. ``grid_dims`` must tile the
    scene with cells of size >= eps (use ``grid_dims_for(lo, hi, eps)``).

    Returns (DbscanResult, overflowed). Labels follow the same contract as
    the faithful tier: cluster root = min original index, noise = -1.
    """
    n, d = points.shape
    eps_f = jnp.asarray(eps, jnp.float32)
    bins = bin_points(points, jnp.asarray(scene_lo, jnp.float32), eps_f,
                      grid_dims, capacity)
    nbr_map = jnp.asarray(stencil_neighbor_map(grid_dims))
    ncells, cap = bins.num_cells, capacity

    # --- Phase 1: core classification (fused counting kernel). -------------
    counts_cells = kops.cell_stencil_counts(bins.cell_pts, nbr_map, eps_f,
                                            interpret=interpret)
    counts_flat = jnp.concatenate(
        [counts_cells.reshape(-1), jnp.zeros((cap,), jnp.int32)])
    counts = counts_flat[bins.slot_of_point]
    core = counts >= min_pts

    core_slots = _scatter_slots(core, False, bins, dtype=jnp.bool_)

    # --- Phase 2: union fixpoint (min-label kernel + hook/compress). -------
    parent0 = jnp.arange(n, dtype=jnp.int32)

    def min_label_pass(parent):
        lab_slots = _scatter_slots(jnp.where(core, parent, SENTINEL_LABEL),
                                   SENTINEL_LABEL, bins)
        m_cells = kops.cell_stencil_min_label(bins.cell_pts, lab_slots,
                                              core_slots, nbr_map, eps_f,
                                              interpret=interpret)
        m_flat = jnp.concatenate(
            [m_cells.reshape(-1), jnp.full((cap,), SENTINEL_LABEL, jnp.int32)])
        return m_flat[bins.slot_of_point]

    def cond(state):
        _, changed, r = state
        return changed & (r < max_rounds)

    def body(state):
        parent, _, r = state
        m = min_label_pass(parent)
        m = jnp.where(core & (m != SENTINEL_LABEL), m, parent)
        tgt = jnp.where(core, parent, n - 1)
        upd = jnp.where(core, jnp.minimum(m, parent), parent[tgt])
        parent2 = parent.at[tgt].min(upd)
        parent2 = union_find.compress(parent2)
        return parent2, jnp.any(parent2 != parent), r + 1

    parent, _, rounds = jax.lax.while_loop(cond, body, (parent0, jnp.bool_(True), jnp.int32(0)))

    # --- Border assignment: min core-neighbor root. -------------------------
    cand = min_label_pass(parent)
    border_ok = ~core & (cand != SENTINEL_LABEL)
    cand_safe = jnp.where(cand == SENTINEL_LABEL, 0, cand)
    resolved = union_find.compress(jnp.where(core, parent, jnp.where(border_ok, cand_safe, parent0)))
    labels = jnp.where(core | border_ok, resolved, NOISE).astype(jnp.int32)

    return DbscanResult(labels=labels, core_mask=core, num_rounds=rounds), bins.overflowed


class GridAutoInfo(NamedTuple):
    """Retry observability for ``fdbscan_grid_auto`` (mirrors the engine's
    ``BufferedCsr`` contract: never fail silently on capacity tuning)."""
    attempts: int   # passes taken (1 = zero-retry fast path)
    capacity: int   # cell capacity the successful attempt used
    overflowed: bool  # whether ANY attempt overflowed (i.e. retries happened)


def fdbscan_grid_auto(points: jax.Array, eps, min_pts: int, *, scene_lo,
                      scene_hi, capacity: int = 64, max_doublings: int = 6,
                      with_info: bool = False, **kw):
    """Auto-tuning driver (the paper's §5 future-work item, adapted): run
    the TPU-native FDBSCAN and, on capacity overflow, re-bin with doubled
    cell capacity — the recoverable analogue of the adjacency-graph
    variant's documented out-of-memory failure (§4.3.1). Host-side retry
    loop; each attempt is a fresh jit specialization.

    With ``with_info=True`` returns (DbscanResult, GridAutoInfo) so callers
    can see how many re-bins the capacity heuristic cost."""
    dims = grid_dims_for(scene_lo, scene_hi, float(eps))
    cap = capacity
    for attempt in range(1, max_doublings + 2):
        res, overflowed = fdbscan_grid(points, eps, min_pts, scene_lo=scene_lo,
                                       grid_dims=dims, capacity=cap, **kw)
        if not bool(overflowed):
            if with_info:
                return res, GridAutoInfo(attempts=attempt, capacity=cap,
                                         overflowed=attempt > 1)
            return res
        cap *= 2
    raise RuntimeError(
        f"fdbscan_grid_auto: capacity {cap // 2} still overflows after "
        f"{max_doublings} doublings (n={points.shape[0]}, dims={dims})")
