"""Linear BVH construction in pure JAX (paper §4.2.1).

Construction follows Karras (2012): every internal node's leaf range is a
purely per-node function of the Morton-code ``delta`` operator, so the whole
hierarchy builds with one ``vmap`` — the functional analogue of the
GPU-parallel build. ArborX switched to Apetrei (2014) for construction speed
and then *recovered Karras' node ordering* to keep rope-based stackless
traversal (Prokopenko & Lebrun-Grandié 2024); here both formulations reduce to
the same range arithmetic, which we exploit to compute ropes (escape indices)
in closed form instead of a second bottom-up pass:

  For a node whose leaf range ends at ``l`` (l < n-1), the lowest ancestor
  that contains leaf ``l+1`` is the unique internal node P whose split is at
  ``l`` (split positions are a permutation of 0..n-2). The rope is P's right
  child: ``leaf(l+1)`` if P's range ends at ``l+1`` else ``internal(l+1)``.
  Nodes ending at ``n-1`` rope to the sentinel.

Node numbering (ArborX convention): internal nodes are ``0 .. n-2`` (root is
0), leaf k (in Morton-sorted order) is node ``(n-1) + k``. ``SENTINEL = -1``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import morton as _morton

SENTINEL = jnp.int32(-1)

__all__ = ["Bvh", "build_bvh", "SENTINEL"]


class Bvh(NamedTuple):
    """Array-of-structs LBVH. n leaves, n-1 internal nodes, ids per module doc."""

    # Sorted leaf order: permutation from sorted leaf k -> original point index.
    leaf_perm: jax.Array          # (n,) int32
    # Children of internal nodes (node ids). (n-1,)
    left_child: jax.Array
    right_child: jax.Array
    # Escape indices for ALL nodes (internal 0..n-2 then leaves n-1..2n-2).
    rope: jax.Array               # (2n-1,) int32
    # AABBs for all nodes, same indexing as rope.
    node_lo: jax.Array            # (2n-1, d)
    node_hi: jax.Array            # (2n-1, d)
    # Leaf range (inclusive) covered by each internal node. (n-1,)
    range_left: jax.Array
    range_right: jax.Array

    @property
    def num_leaves(self) -> int:
        return self.leaf_perm.shape[0]

    def leaf_node_id(self, k: jax.Array) -> jax.Array:
        return k + (self.num_leaves - 1)


def _karras_ranges(delta):
    """Given delta(i, j) -> int (vectorized over i), compute per-internal-node
    (range_left, range_right, split) with Karras' doubling + binary search."""

    def per_node(i):
        d = jnp.sign(delta(i, i + 1) - delta(i, i - 1)).astype(jnp.int32)
        d = jnp.where(d == 0, jnp.int32(1), d)  # ties only possible interiorly
        delta_min = delta(i, i - d)

        # Exponential search for the range-length upper bound.
        def cond_up(lm):
            return delta(i, i + lm * d) > delta_min

        l_max = jax.lax.while_loop(cond_up, lambda lm: lm * 2, jnp.int32(2))

        # Binary search the exact other end.
        def bin_step(carry, _):
            l, t = carry
            go = delta(i, i + (l + t) * d) > delta_min
            l = jnp.where(go & (t > 0), l + t, l)
            return (l, t // 2), None

        # l_max <= 2n so 32 halvings always reach t == 0.
        (l, _), _ = jax.lax.scan(bin_step, (jnp.int32(0), l_max // 2), None, length=32)
        j = i + l * d

        # Split search: find largest s with delta(i, i + (s+t)*d) > delta_node.
        delta_node = delta(i, j)

        def split_step(carry, _):
            s, t = carry
            t_here = (t + 1) // 2  # ceil halving sequence
            go = delta(i, i + (s + t_here) * d) > delta_node
            s = jnp.where(go & (t > 0), s + t_here, s)
            t = jnp.where(t > 1, t_here, jnp.int32(0))
            return (s, t), None

        (s, _), _ = jax.lax.scan(split_step, (jnp.int32(0), l), None, length=32)
        gamma = i + s * d + jnp.minimum(d, 0)

        first = jnp.minimum(i, j)
        last = jnp.maximum(i, j)
        return first, last, gamma

    return per_node


@partial(jax.jit, static_argnames=("use_64bit",))
def build_bvh(points: jax.Array, scene_lo: jax.Array, scene_hi: jax.Array,
              use_64bit: bool = True) -> Bvh:
    """Build an LBVH over (n, 3) float32 points (leaf AABB = point)."""
    return build_bvh_objects(points, points, scene_lo, scene_hi, use_64bit=use_64bit)


@partial(jax.jit, static_argnames=("use_64bit",))
def build_bvh_objects(leaf_lo: jax.Array, leaf_hi: jax.Array,
                      scene_lo: jax.Array, scene_hi: jax.Array,
                      use_64bit: bool = True) -> Bvh:
    """Build an LBVH over boxed objects (paper §4.3.4 mixed cells+points tree:
    'it only requires bounding volumes for a set of objects'). Morton codes are
    taken from box centers. n must be >= 2."""
    n = leaf_lo.shape[0]
    centers = (leaf_lo + leaf_hi) * 0.5
    unit = _morton.normalize_points(centers, scene_lo, scene_hi)

    if use_64bit:
        hi, lo = _morton.morton64(unit)
        perm = _morton.sort_by_morton64(hi, lo).astype(jnp.int32)
        hi_s, lo_s = hi[perm], lo[perm]

        def delta(i, j):
            return _morton.common_prefix_length64(hi_s, lo_s, jnp.asarray(i), jnp.asarray(j))
    else:
        codes = _morton.morton32(unit)
        perm = _morton.sort_by_morton32(codes).astype(jnp.int32)
        codes_s = codes[perm]

        def delta(i, j):
            return _morton.common_prefix_length32(codes_s, jnp.asarray(i), jnp.asarray(j))

    internal_ids = jnp.arange(n - 1, dtype=jnp.int32)
    first, last, gamma = jax.vmap(_karras_ranges(delta))(internal_ids)

    # Children: leaf if the child range is a single leaf.
    left = jnp.where(first == gamma, gamma + (n - 1), gamma)
    right = jnp.where(last == gamma + 1, gamma + 1 + (n - 1), gamma + 1)

    # --- Ropes in closed form (see module docstring). ---
    # split_node[g] = internal node whose split position is g.
    split_node = jnp.zeros((n - 1,), jnp.int32).at[gamma].set(internal_ids)
    split_end = jnp.zeros((n - 1,), jnp.int32).at[gamma].set(last)

    def rope_of(end):  # end = inclusive leaf-range end of the node
        is_last = end >= n - 1
        end_c = jnp.clip(end, 0, n - 2)
        p_end = split_end[end_c]
        r = jnp.where(p_end == end + 1, end + 1 + (n - 1), end + 1)
        return jnp.where(is_last, SENTINEL, r).astype(jnp.int32)

    rope_internal = rope_of(last)
    rope_leaf = rope_of(jnp.arange(n, dtype=jnp.int32))
    rope = jnp.concatenate([rope_internal, rope_leaf])

    # --- AABBs: leaves from points, internal via bottom-up fixpoint. ---
    dim = leaf_lo.shape[1]
    big = jnp.full((n - 1, dim), jnp.inf, leaf_lo.dtype)
    node_lo0 = jnp.concatenate([big, leaf_lo[perm]])
    node_hi0 = jnp.concatenate([-big, leaf_hi[perm]])
    ready0 = jnp.concatenate([jnp.zeros(n - 1, bool), jnp.ones(n, bool)])

    def fix_cond(state):
        _, _, ready = state
        return ~jnp.all(ready)

    def fix_body(state):
        nlo, nhi, ready = state
        l_lo, l_hi, l_rdy = nlo[left], nhi[left], ready[left]
        r_lo, r_hi, r_rdy = nlo[right], nhi[right], ready[right]
        new_lo = jnp.minimum(l_lo, r_lo)
        new_hi = jnp.maximum(l_hi, r_hi)
        ok = l_rdy & r_rdy
        nlo = nlo.at[internal_ids].set(jnp.where(ok[:, None], new_lo, nlo[internal_ids]))
        nhi = nhi.at[internal_ids].set(jnp.where(ok[:, None], new_hi, nhi[internal_ids]))
        ready = ready.at[internal_ids].set(ready[internal_ids] | ok)
        return nlo, nhi, ready

    node_lo, node_hi, _ = jax.lax.while_loop(fix_cond, fix_body, (node_lo0, node_hi0, ready0))

    return Bvh(
        leaf_perm=perm,
        left_child=left,
        right_child=right,
        rope=rope,
        node_lo=node_lo,
        node_hi=node_hi,
        range_left=first,
        range_right=last,
    )
