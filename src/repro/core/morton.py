"""Morton (Z-order) codes for spatial sorting.

The paper (§4.2.2) shows that 32-bit Morton codes (10 bits/dim in 3D) collapse
for clustered scientific data — 64% of the benchmark points shared a code —
and that moving to 64-bit codes (21 bits/dim) removes nearly all duplicates.

JAX runs with 32-bit integers by default (x64 disabled), so 64-bit codes are
represented as a ``(hi, lo)`` pair of uint32 with lexicographic ordering —
bit-identical ordering to a native uint64 sort.

Bit layout of the 63-bit 3D code (x is the *highest* interleaved bit, matching
the usual ``expand(x) << 2 | expand(y) << 1 | expand(z)`` convention):

  bits  0..29 : interleave of coordinate bits 0..9
  bits 30..59 : interleave of coordinate bits 10..19
  bits 60..62 : coordinate bits 20 (z at 60, y at 61, x at 62)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

U32 = jnp.uint32

__all__ = [
    "normalize_points",
    "morton32",
    "morton64",
    "sort_by_morton32",
    "sort_by_morton64",
    "common_prefix_length32",
    "common_prefix_length64",
]


def normalize_points(points: jax.Array, scene_min: jax.Array, scene_max: jax.Array) -> jax.Array:
    """Map points into [0, 1)^d given the scene bounding box."""
    extent = jnp.maximum(scene_max - scene_min, jnp.finfo(points.dtype).tiny)
    unit = (points - scene_min) / extent
    # Clamp so that max-corner points stay inside the last bin.
    return jnp.clip(unit, 0.0, 1.0 - jnp.finfo(points.dtype).eps)


def _expand_bits_10(v: jax.Array) -> jax.Array:
    """Spread the low 10 bits of ``v``: bit i -> bit 3i (classic magic numbers)."""
    v = v.astype(U32) & U32(0x3FF)
    v = (v * U32(0x00010001)) & U32(0xFF0000FF)
    v = (v * U32(0x00000101)) & U32(0x0F00F00F)
    v = (v * U32(0x00000011)) & U32(0xC30C30C3)
    v = (v * U32(0x00000005)) & U32(0x49249249)
    return v


def _interleave10(x: jax.Array, y: jax.Array, z: jax.Array) -> jax.Array:
    """30-bit interleave of three 10-bit integers; x occupies the high bit of
    each 3-bit group."""
    return (_expand_bits_10(x) << 2) | (_expand_bits_10(y) << 1) | _expand_bits_10(z)


def _quantize(unit_points: jax.Array, bins: int) -> jax.Array:
    """[0,1)^d floats -> uint32 bin ids in [0, bins). The clamp happens in
    FLOAT space, before the integer cast: out-of-range inputs (unnormalized
    points, the BIG=1e15 ghost fill) would otherwise overflow the cast —
    float->int of a value past the dtype range is undefined (staticcheck
    rule W1) — whereas the clamped value always fits. uint32 pair idiom
    throughout: no signed intermediary, no x64 dependence."""
    assert jnp.issubdtype(unit_points.dtype, jnp.floating), unit_points.dtype
    q = jnp.clip(jnp.floor(unit_points * float(bins)), 0.0, float(bins - 1))
    q = q.astype(U32)
    assert q.dtype == U32, q.dtype
    return q


def morton32(unit_points: jax.Array) -> jax.Array:
    """32-bit (30 used) Morton codes for points in [0,1)^3. Shape (n,3)->(n,)."""
    q = _quantize(unit_points, 1 << 10)
    return _interleave10(q[..., 0], q[..., 1], q[..., 2])


def morton64(unit_points: jax.Array) -> tuple[jax.Array, jax.Array]:
    """63-bit Morton codes for points in [0,1)^3 as a (hi, lo) uint32 pair.

    21 bits per dimension. float32 has a 24-bit mantissa so quantization to
    2^21 bins is exact for unit-interval inputs.
    """
    q = _quantize(unit_points, 1 << 21)
    x, y, z = q[..., 0], q[..., 1], q[..., 2]

    low = _interleave10(x & U32(0x3FF), y & U32(0x3FF), z & U32(0x3FF))          # bits 0..29
    mid = _interleave10((x >> 10) & U32(0x3FF), (y >> 10) & U32(0x3FF), (z >> 10) & U32(0x3FF))  # bits 30..59
    top = (((x >> 20) & U32(1)) << 2) | (((y >> 20) & U32(1)) << 1) | ((z >> 20) & U32(1))       # bits 60..62

    lo = low | (mid << 30)                      # mid bits 0..1 land at 30..31
    hi = (mid >> 2) | (top << 28)               # mid bits 2..29 at 0..27, top at 28..30
    return hi, lo


def sort_by_morton32(codes: jax.Array) -> jax.Array:
    """Stable argsort of 32-bit codes (ties keep index order => deterministic)."""
    return jnp.argsort(codes, stable=True)


def sort_by_morton64(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Stable lexicographic argsort of (hi, lo) uint32 pairs."""
    return jnp.lexsort((lo, hi))


def common_prefix_length32(codes: jax.Array, i: jax.Array, j: jax.Array) -> jax.Array:
    """Karras' delta operator for 32-bit codes with index tie-breaking.

    Returns the length of the common bit prefix of codes[i], codes[j]; when
    the codes are equal, returns 32 + clz(i ^ j) so equal-code runs still form
    a balanced hierarchy. Out-of-range j yields -1 (Karras convention).
    """
    n = codes.shape[0]
    valid = (j >= 0) & (j < n)
    j_safe = jnp.clip(j, 0, n - 1)
    ci, cj = codes[i], codes[j_safe]
    x = ci ^ cj
    idx_x = (i.astype(U32) ^ j_safe.astype(U32))
    d = jnp.where(x != 0, jax.lax.clz(x), U32(32) + jax.lax.clz(idx_x))
    return jnp.where(valid, d.astype(jnp.int32), -1)


def common_prefix_length64(hi: jax.Array, lo: jax.Array, i: jax.Array, j: jax.Array) -> jax.Array:
    """delta for 63-bit (hi, lo) codes with index tie-breaking (≤ 96 bits)."""
    n = hi.shape[0]
    valid = (j >= 0) & (j < n)
    j_safe = jnp.clip(j, 0, n - 1)
    xh = hi[i] ^ hi[j_safe]
    xl = lo[i] ^ lo[j_safe]
    idx_x = (i.astype(U32) ^ j_safe.astype(U32))
    d = jnp.where(
        xh != 0,
        jax.lax.clz(xh),
        jnp.where(xl != 0, U32(32) + jax.lax.clz(xl), U32(64) + jax.lax.clz(idx_x)),
    )
    return jnp.where(valid, d.astype(jnp.int32), -1)
