"""Euclidean minimum spanning tree (paper §3.2: ArborX's other clustering
algorithm; Prokopenko, Sao & Lebrun-Grandié 2023b — a single-tree Borůvka
on GPUs). The HDBSCAN* prerequisite (paper §5 future work).

Borůvka rounds in pure JAX:
  each round, every point finds its nearest neighbor in a DIFFERENT
  component (BVH traversal pruned by the best candidate so far AND by
  component identity), each component keeps its minimum outgoing edge
  (scatter-min), the edges join the MST, and components merge
  (union-find). O(log n) rounds; all shapes fixed.

Component-aware pruning mirrors the paper's algorithm: a subtree whose
leaf range lies entirely in the query's component is skipped — here
detected via per-node component intervals recomputed each round (a node
is skippable when every leaf below it has the query's root AND the node
interval is degenerate)."""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import union_find
from repro.core.bvh import Bvh, SENTINEL, build_bvh
from repro.core.geometry import scene_bounds, point_aabb_dist2

__all__ = ["EmstResult", "emst"]

_STACK_DEPTH = 96


class EmstResult(NamedTuple):
    edges: jax.Array      # (n-1, 2) int32 — MST edges (original indices)
    weights: jax.Array    # (n-1,) float32 — euclidean lengths
    total_weight: jax.Array
    rounds: jax.Array


def _node_component_intervals(bvh: Bvh, comp_sorted: jax.Array):
    """Per-node [min, max] component id over its leaf range; a node with
    min == max is entirely inside one component (skippable for queries from
    that component). Computed per round with the bottom-up fixpoint."""
    n = bvh.num_leaves
    inf = jnp.iinfo(jnp.int32).max
    lo0 = jnp.concatenate([jnp.full((n - 1,), inf, jnp.int32), comp_sorted])
    hi0 = jnp.concatenate([jnp.full((n - 1,), -1, jnp.int32), comp_sorted])
    ready0 = jnp.concatenate([jnp.zeros(n - 1, bool), jnp.ones(n, bool)])
    ids = jnp.arange(n - 1, dtype=jnp.int32)

    def cond(state):
        return ~jnp.all(state[2])

    def body(state):
        lo, hi, ready = state
        l, r = bvh.left_child, bvh.right_child
        ok = ready[l] & ready[r]
        lo = lo.at[ids].set(jnp.where(ok, jnp.minimum(lo[l], lo[r]), lo[ids]))
        hi = hi.at[ids].set(jnp.where(ok, jnp.maximum(hi[l], hi[r]), hi[ids]))
        ready = ready.at[ids].set(ready[ids] | ok)
        return lo, hi, ready

    lo, hi, _ = jax.lax.while_loop(cond, body, (lo0, hi0, ready0))
    return lo, hi


def _nearest_other_component(bvh: Bvh, points: jax.Array, comp: jax.Array):
    """For each point, (distance², index) of the nearest point whose
    component differs. Stack traversal with best-so-far pruning."""
    n = bvh.num_leaves
    comp_sorted = comp[bvh.leaf_perm]
    clo, chi = _node_component_intervals(bvh, comp_sorted)

    def one(center, my_comp):
        stack0 = jnp.full((_STACK_DEPTH,), SENTINEL, jnp.int32).at[0].set(0)

        def cond(state):
            return state[0] > 0

        def body(state):
            sp, stack, best_d, best_i = state
            node = stack[sp - 1]
            sp = sp - 1
            is_leaf = node >= n - 1

            sorted_idx = jnp.clip(node - (n - 1), 0, n - 1)
            orig = bvh.leaf_perm[sorted_idx]
            d_leaf = jnp.sum((points[orig] - center) ** 2)
            hit = is_leaf & (comp[orig] != my_comp) & (d_leaf < best_d)
            best_i = jnp.where(hit, orig, best_i)
            best_d = jnp.where(hit, d_leaf, best_d)

            node_c = jnp.clip(node, 0, n - 2)
            l, r = bvh.left_child[node_c], bvh.right_child[node_c]

            def child_push(sp, stack, child):
                d = point_aabb_dist2(center, bvh.node_lo[child],
                                     bvh.node_hi[child])
                # skip: outside pruning radius, or entirely my component
                same = (clo[child] == chi[child]) & (clo[child] == my_comp)
                push = (~is_leaf) & (d < best_d) & ~same
                stack = stack.at[sp].set(jnp.where(push, child, stack[sp]))
                return sp + push.astype(jnp.int32), stack

            # push far-first so the near child tightens the bound first
            dl = point_aabb_dist2(center, bvh.node_lo[l], bvh.node_hi[l])
            dr = point_aabb_dist2(center, bvh.node_lo[r], bvh.node_hi[r])
            near = jnp.where(dl <= dr, l, r)
            far = jnp.where(dl <= dr, r, l)
            sp, stack = child_push(sp, stack, far)
            sp, stack = child_push(sp, stack, near)
            return sp, stack, best_d, best_i

        _, _, best_d, best_i = jax.lax.while_loop(
            cond, body, (jnp.int32(1), stack0, jnp.float32(jnp.inf),
                         jnp.int32(-1)))
        return best_d, best_i

    return jax.vmap(one)(points, comp)


@jax.jit
def emst(points: jax.Array) -> EmstResult:
    """Euclidean MST over (n, d) points via BVH-accelerated Borůvka."""
    n = points.shape[0]
    lo, hi = scene_bounds(points)
    bvh = build_bvh(points, lo, hi)

    # buffers sized n: slot n-1 is a write-trash slot for non-kept lanes
    # (dummy writes must never alias a real slot — scatter order is undefined)
    edges0 = jnp.full((n, 2), -1, jnp.int32)
    weights0 = jnp.zeros((n,), jnp.float32)
    comp0 = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        comp, _, _, n_edges, r = state
        return (n_edges < n - 1) & (r < 32)

    def body(state):
        comp, edges, weights, n_edges, r = state
        d2, j = _nearest_other_component(bvh, points, comp)

        # per-component minimum outgoing edge (scatter-min on packed keys):
        # key = dist-rank-free trick: scatter-min f32 distances per root,
        # then identify the argmin by equality (ties broken by min index).
        INF = jnp.float32(jnp.inf)
        best_d = jnp.full((n,), INF, jnp.float32).at[comp].min(d2)
        is_min = (d2 <= best_d[comp]) & (j >= 0)
        # one winner per component: the minimum point index among is_min
        winner = jnp.full((n,), n, jnp.int32).at[
            jnp.where(is_min, comp, n - 1)].min(
            jnp.where(is_min, jnp.arange(n, dtype=jnp.int32), n))
        i_sel = winner[comp]                       # per point: its comp's winner
        picked = (jnp.arange(n) == i_sel) & is_min

        # Boruvka double-counting guard: the SAME pair {i, j} is picked from
        # both sides iff j also picked i (mutual); drop the copy whose root
        # is larger. (Dedup must use the full pair identity — two components
        # can legitimately pick different edges sharing an endpoint.)
        a = jnp.where(picked, jnp.arange(n, dtype=jnp.int32), -1)
        b = jnp.where(picked, j, -1)
        j_safe = jnp.clip(j, 0, n - 1)
        mutual = picked & picked[j_safe] & (j[j_safe] == jnp.arange(n)) \
            & (comp > comp[j_safe])
        keep = picked & ~mutual

        # append kept edges into the fixed buffer via cumulative offsets;
        # non-kept lanes write to the dedicated trash slot n-1
        offs = jnp.cumsum(keep.astype(jnp.int32)) - 1
        slot = jnp.where(keep, n_edges + offs, n - 1)
        edges = edges.at[slot].set(
            jnp.where(keep[:, None], jnp.stack([a, b], 1), edges[slot]))
        weights = weights.at[slot].set(
            jnp.where(keep, jnp.sqrt(d2), weights[slot]))
        n_edges = n_edges + jnp.sum(keep.astype(jnp.int32))

        # merge: union every picked edge, ITERATED to a fixpoint — a single
        # hook+compress can lose unions when two edges scatter-min the same
        # root, and a lost union makes the component re-pick (and re-append)
        # the same edge next round.
        aa, bb = jnp.clip(a, 0, n - 1), jnp.clip(b, 0, n - 1)

        def m_cond(st):
            return st[1]

        def m_body(st):
            c, _ = st
            c2 = union_find.compress(union_find.hook_min(c, aa, bb, picked))
            return c2, jnp.any(c2 != c)

        c1 = union_find.compress(union_find.hook_min(comp, aa, bb, picked))
        comp, _ = jax.lax.while_loop(m_cond, m_body,
                                     (c1, jnp.any(c1 != comp)))
        return comp, edges, weights, n_edges, r + 1

    comp, edges, weights, n_edges, rounds = jax.lax.while_loop(
        cond, body, (comp0, edges0, weights0, jnp.int32(0), jnp.int32(0)))
    edges, weights = edges[: n - 1], weights[: n - 1]
    return EmstResult(edges=edges, weights=weights,
                      total_weight=jnp.sum(weights), rounds=rounds)
