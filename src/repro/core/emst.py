"""Euclidean minimum spanning tree (paper §3.2: ArborX's other clustering
algorithm; Prokopenko, Sao & Lebrun-Grandié 2023b — a single-tree Borůvka
on GPUs). The HDBSCAN* prerequisite (paper §5 future work).

Borůvka rounds in pure JAX:
  each round, every point finds its nearest neighbor in a DIFFERENT
  component (BVH traversal pruned by the best candidate so far AND by
  component identity), each component keeps its minimum outgoing edge
  (scatter-min), the edges join the MST, and components merge
  (union-find). O(log n) rounds; all shapes fixed.

Component-aware pruning mirrors the paper's algorithm: a subtree whose
leaf range lies entirely in the query's component is skipped — here
detected via per-node component intervals recomputed each round (a node
is skippable when every leaf below it has the query's root AND the node
interval is degenerate).

The traversal itself is the query engine's ordered-stack nearest core
(``core.query.traverse_nearest_stack``, the same loop behind the
``nearest(k)`` predicate) with a component-filtered leaf update and a
component-interval push gate; the intervals come from the engine's
generic bottom-up ``node_reduce``."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import union_find
from repro.core.bvh import Bvh, build_bvh
from repro.core.geometry import scene_bounds
from repro.core.query import node_reduce, traverse_nearest_stack

__all__ = ["EmstResult", "emst"]


class EmstResult(NamedTuple):
    edges: jax.Array      # (n-1, 2) int32 — MST edges (original indices)
    weights: jax.Array    # (n-1,) float32 — euclidean lengths
    total_weight: jax.Array
    rounds: jax.Array


def _node_component_intervals(bvh: Bvh, comp_sorted: jax.Array):
    """Per-node [min, max] component id over its leaf range; a node with
    min == max is entirely inside one component (skippable for queries from
    that component). Recomputed per round with the engine's generic
    bottom-up reduction."""
    inf = jnp.iinfo(jnp.int32).max
    return node_reduce(
        bvh, (comp_sorted, comp_sorted),
        lambda a, b: (jnp.minimum(a[0], b[0]), jnp.maximum(a[1], b[1])),
        (jnp.int32(inf), jnp.int32(-1)))


def _nearest_other_component(bvh: Bvh, points: jax.Array, comp: jax.Array):
    """For each point, (distance², index) of the nearest point whose
    component differs: the engine's ordered-stack nearest traversal with a
    component filter in the leaf update and a component-interval skip in
    the push gate (best-so-far pruning comes from the carry)."""
    comp_sorted = comp[bvh.leaf_perm]
    clo, chi = _node_component_intervals(bvh, comp_sorted)

    def push_fn(my_comp, carry, child, d2):
        best_d, _ = carry
        # skip: outside pruning radius, or entirely my component
        same = (clo[child] == chi[child]) & (clo[child] == my_comp)
        return (d2 < best_d) & ~same

    def leaf_fn(my_comp, carry, obj, d2):
        best_d, best_i = carry
        hit = (comp[obj] != my_comp) & (d2 < best_d)
        return jnp.where(hit, d2, best_d), jnp.where(hit, obj, best_i)

    return traverse_nearest_stack(
        bvh, points, comp, push_fn, leaf_fn,
        (jnp.float32(jnp.inf), jnp.int32(-1)))


@jax.jit
def emst(points: jax.Array) -> EmstResult:
    """Euclidean MST over (n, d) points via BVH-accelerated Borůvka."""
    n = points.shape[0]
    lo, hi = scene_bounds(points)
    bvh = build_bvh(points, lo, hi)

    # buffers sized n: slot n-1 is a write-trash slot for non-kept lanes
    # (dummy writes must never alias a real slot — scatter order is undefined)
    edges0 = jnp.full((n, 2), -1, jnp.int32)
    weights0 = jnp.zeros((n,), jnp.float32)
    comp0 = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        comp, _, _, n_edges, r = state
        return (n_edges < n - 1) & (r < 32)

    def body(state):
        comp, edges, weights, n_edges, r = state
        d2, j = _nearest_other_component(bvh, points, comp)

        # per-component minimum outgoing edge (scatter-min on packed keys):
        # key = dist-rank-free trick: scatter-min f32 distances per root,
        # then identify the argmin by equality (ties broken by min index).
        INF = jnp.float32(jnp.inf)
        best_d = jnp.full((n,), INF, jnp.float32).at[comp].min(d2)
        is_min = (d2 <= best_d[comp]) & (j >= 0)
        # one winner per component: the minimum point index among is_min
        winner = jnp.full((n,), n, jnp.int32).at[
            jnp.where(is_min, comp, n - 1)].min(
            jnp.where(is_min, jnp.arange(n, dtype=jnp.int32), n))
        i_sel = winner[comp]                       # per point: its comp's winner
        picked = (jnp.arange(n) == i_sel) & is_min

        # Boruvka double-counting guard: the SAME pair {i, j} is picked from
        # both sides iff j also picked i (mutual); drop the copy whose root
        # is larger. (Dedup must use the full pair identity — two components
        # can legitimately pick different edges sharing an endpoint.)
        a = jnp.where(picked, jnp.arange(n, dtype=jnp.int32), -1)
        b = jnp.where(picked, j, -1)
        j_safe = jnp.clip(j, 0, n - 1)
        mutual = picked & picked[j_safe] & (j[j_safe] == jnp.arange(n)) \
            & (comp > comp[j_safe])
        keep = picked & ~mutual

        # append kept edges into the fixed buffer via cumulative offsets;
        # non-kept lanes write to the dedicated trash slot n-1
        offs = jnp.cumsum(keep.astype(jnp.int32)) - 1
        slot = jnp.where(keep, n_edges + offs, n - 1)
        edges = edges.at[slot].set(
            jnp.where(keep[:, None], jnp.stack([a, b], 1), edges[slot]))
        weights = weights.at[slot].set(
            jnp.where(keep, jnp.sqrt(d2), weights[slot]))
        n_edges = n_edges + jnp.sum(keep.astype(jnp.int32))

        # merge: union every picked edge, ITERATED to a fixpoint — a single
        # hook+compress can lose unions when two edges scatter-min the same
        # root, and a lost union makes the component re-pick (and re-append)
        # the same edge next round.
        aa, bb = jnp.clip(a, 0, n - 1), jnp.clip(b, 0, n - 1)

        def m_cond(st):
            return st[1]

        def m_body(st):
            c, _ = st
            c2 = union_find.compress(union_find.hook_min(c, aa, bb, picked))
            return c2, jnp.any(c2 != c)

        c1 = union_find.compress(union_find.hook_min(comp, aa, bb, picked))
        comp, _ = jax.lax.while_loop(m_cond, m_body,
                                     (c1, jnp.any(c1 != comp)))
        return comp, edges, weights, n_edges, r + 1

    comp, edges, weights, n_edges, rounds = jax.lax.while_loop(
        cond, body, (comp0, edges0, weights0, jnp.int32(0), jnp.int32(0)))
    edges, weights = edges[: n - 1], weights[: n - 1]
    return EmstResult(edges=edges, weights=weights,
                      total_weight=jnp.sum(weights), rounds=rounds)
