"""DBSCAN variants from the paper (§4.3), faithful tier in pure JAX.

Every ε-search here — neighbor counts with minPts early exit, the
min-label union passes, graph_cc's bounded neighbor buffers, pair
capture — is one ``core.query`` engine call (``within`` predicates +
fused callbacks / the fixed-capacity output protocol / the pair
backend); this module only contributes the clustering logic around it.

Variants, matching the Fig. 4 improvement ladder:

* ``dbscan_graph_cc``   — initial implementation (§4.3.1): materialize the
  ε-adjacency graph (bounded neighbor buffers — the paper's documented memory
  drawback), then run connected components (ECL-CC analogue).
* ``fdbscan``           — "fused" DBSCAN (§4.3.3): no neighbor storage.
  Phase 1 counts ε-neighbors with EARLY TERMINATION at minPts (§4.1.2);
  Phase 2 runs min-label hook+compress rounds where each round's candidate
  labels come straight from a fused traversal callback (§4.1.1), O(n) memory.
* ``fdbscan_pair``      — FDBSCAN whose union phase uses PAIR TRAVERSAL
  (§4.2.3, improvement (7)): each unordered pair (i, j), i<j in Morton order,
  is visited exactly once; cross-root pairs are captured into a small
  per-query buffer and hooked. Buffer overflow is legal: every overflowing
  round strictly reduces the number of components, so the outer fixpoint
  terminates.
* ``fdbscan_densebox``  — FDBSCAN-DenseBox (§4.3.4): mixed BVH over dense
  ε/√d cells + outside points; dense-cell points are pre-classified core and
  pre-unioned, intra-cell distance tests are eliminated, and a whole cell
  within ε of a query is processed wholesale.

All return int32 labels: core/border points carry their cluster root (the
minimum original index in the component), noise = -1. Cluster-partition
semantics are validated against ``ref_numpy.dbscan_ref``.

Union-find note (DESIGN.md deviation 3): ArborX's ECL-CC uses atomic CAS
hooking; XLA has no atomic CAS, so unions are expressed as deterministic
scatter-min hooking + pointer jumping (same disjoint-set family).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import union_find
from repro.core.bvh import Bvh, build_bvh, build_bvh_objects
from repro.core.cell_grid import CellGrid, build_cell_grid, cell_box
from repro.core.geometry import scene_bounds as _scene
from repro.core.query import query, query_count, query_fixed, within

NOISE = jnp.int32(-1)

__all__ = [
    "NOISE",
    "DbscanResult",
    "count_neighbors",
    "min_core_label_on",
    "union_rounds",
    "dbscan_graph_cc",
    "fdbscan",
    "fdbscan_pair",
    "fdbscan_densebox",
]


class DbscanResult(NamedTuple):
    labels: jax.Array       # (n,) int32; cluster root or -1 (noise)
    core_mask: jax.Array    # (n,) bool
    num_rounds: jax.Array   # () int32 — union fixpoint rounds taken


# ---------------------------------------------------------------------------
# Neighbor counting (phase 1) — fused callback + early termination (§4.1.2)
# ---------------------------------------------------------------------------

def count_neighbors(bvh: Bvh, points: jax.Array, queries: jax.Array, eps,
                    min_pts: int | None = None, use_stack: bool = False) -> jax.Array:
    """ε-neighbor counts for each query (neighborhood includes the point
    itself). With ``min_pts`` set, counting STOPS at min_pts (early
    termination; returned counts saturate there). ``points`` is kept in
    the signature for backward compatibility — the engine tests against
    leaf volumes directly."""
    return query_count(bvh, within(queries, jnp.asarray(eps, points.dtype)),
                       stop_at=min_pts,
                       backend="stack" if use_stack else "stackless")


def _core_mask(bvh, points, eps, min_pts, early_stop=True, use_stack=False):
    counts = count_neighbors(bvh, points, points, eps,
                             min_pts=min_pts if early_stop else None,
                             use_stack=use_stack)
    return counts >= min_pts


# ---------------------------------------------------------------------------
# Min-label candidate traversal (shared by fdbscan variants)
# ---------------------------------------------------------------------------

def min_core_label_on(bvh: Bvh, query_pts: jax.Array, eps, obj_labels,
                      obj_core, queries_mask, sentinel) -> jax.Array:
    """Engine pass shared by the FDBSCAN variants AND the distributed layer:
    for each query point with ``queries_mask`` set, the min over core
    ε-neighbor OBJECTS j of ``obj_labels[j]`` (``sentinel`` if none).

    ``obj_labels`` / ``obj_core`` are indexed by the TREE's object index —
    decoupled from the query set, so the distributed layer can run local
    queries against a local ∪ ghost tree with exchanged ghost labels.
    The sentinel follows ``obj_labels``'s dtype (int64 global ids at scale)."""
    sentinel = jnp.asarray(sentinel, getattr(obj_labels, "dtype", jnp.int32))

    def fn(best, _qi, j, _d2):
        return (jnp.where(obj_core[j], jnp.minimum(best, obj_labels[j]), best),
                jnp.bool_(False))

    out = query(bvh, within(query_pts, jnp.asarray(eps, query_pts.dtype)),
                fn, sentinel)
    return jnp.where(queries_mask, out, sentinel)


def _min_core_label_pass(bvh, points, eps, parent, core, queries_mask, n):
    """Self-join wrapper: queries == objects == ``points``."""
    return min_core_label_on(bvh, points, eps, parent, core, queries_mask, n)


def _finish_labels(parent, border_candidate, core, n):
    labels = jnp.where(core, parent, jnp.where(border_candidate < n, border_candidate, NOISE))
    # Border candidates were captured against possibly-stale parents; chase.
    labels_safe = jnp.where(labels >= 0, labels, jnp.arange(n, dtype=jnp.int32))
    resolved = union_find.compress(jnp.where(core, parent, labels_safe).astype(jnp.int32))
    return jnp.where(labels >= 0, resolved, NOISE).astype(jnp.int32)


def union_rounds(bvh, points, eps, core, n, max_rounds=64):
    """Fixpoint: hook each core point's root under the min core-neighbor label,
    then pointer-jump. Labels converge to the min original index per cluster.

    Public so the distributed layer can run the same local union fixpoint on a
    per-shard tree before the cross-shard label rounds."""
    parent0 = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        _, changed, r = state
        return changed & (r < max_rounds)

    def body(state):
        parent, _, r = state
        m = _min_core_label_pass(bvh, points, eps, parent, core, core, n)
        m = jnp.where(core, m, n)
        # hook: parent[parent[i]] <- min(., m_i) for core i (scatter-min, det.)
        tgt = jnp.where(core, parent, n - 1)  # dummy target for non-core
        upd = jnp.where(core, jnp.minimum(m, parent), parent[tgt])
        parent2 = parent.at[tgt].min(upd)
        parent2 = union_find.compress(parent2)
        return parent2, jnp.any(parent2 != parent), r + 1

    parent, _, rounds = jax.lax.while_loop(cond, body, (parent0, jnp.bool_(True), jnp.int32(0)))
    return parent, rounds


_union_rounds = union_rounds


@partial(jax.jit, static_argnames=("min_pts", "early_stop", "use_stack", "use_64bit"))
def fdbscan(points: jax.Array, eps, min_pts: int, *, early_stop: bool = True,
            use_stack: bool = False, use_64bit: bool = True) -> DbscanResult:
    """FDBSCAN (§4.3.3): fused traversal + count + union, O(n) memory."""
    n = points.shape[0]
    lo, hi = _scene(points)
    bvh = build_bvh(points, lo, hi, use_64bit=use_64bit)

    core = _core_mask(bvh, points, eps, min_pts, early_stop=early_stop, use_stack=use_stack)
    parent, rounds = _union_rounds(bvh, points, eps, core, n)
    border = _min_core_label_pass(bvh, points, eps, parent, core, ~core, n)
    labels = _finish_labels(parent, border, core, n)
    return DbscanResult(labels=labels, core_mask=core, num_rounds=rounds)


# ---------------------------------------------------------------------------
# Initial implementation (§4.3.1): explicit adjacency graph + CC
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("min_pts", "neighbor_capacity", "use_64bit"))
def dbscan_graph_cc(points: jax.Array, eps, min_pts: int,
                    neighbor_capacity: int = 64, use_64bit: bool = True) -> DbscanResult:
    """The pre-callback baseline: store the ε-graph, then run CC.

    Reproduces the documented drawback — O(n·cap) memory, and the result is
    only correct when no neighborhood exceeds ``neighbor_capacity`` (the
    paper: "storing the found objects results in running out of memory").
    Kept for the Fig. 4 benchmark ladder.
    """
    n = points.shape[0]
    lo, hi = _scene(points)
    bvh = build_bvh(points, lo, hi, use_64bit=use_64bit)

    # The engine's fixed-capacity output protocol IS the documented
    # drawback: surplus neighbors overwrite the last slot.
    nbrs, counts, _overflow = query_fixed(
        bvh, within(points, jnp.asarray(eps, points.dtype)),
        capacity=neighbor_capacity)
    core = counts >= min_pts

    # Core-core edges from the stored graph.
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], nbrs.shape)
    valid = (nbrs >= 0) & core[src] & core[jnp.clip(nbrs, 0, n - 1)]
    parent = union_find.connected_components(n, src.ravel(), jnp.clip(nbrs, 0, n - 1).ravel(),
                                             valid.ravel())
    parent = jnp.where(core, parent, jnp.arange(n, dtype=jnp.int32))

    # Border: min core-neighbor root from the stored graph.
    nbr_safe = jnp.clip(nbrs, 0, n - 1)
    cand = jnp.where((nbrs >= 0) & core[nbr_safe], parent[nbr_safe], n)
    border = jnp.min(cand, axis=1).astype(jnp.int32)
    labels = _finish_labels(parent, border, core, n)
    return DbscanResult(labels=labels, core_mask=core, num_rounds=jnp.int32(1))


# ---------------------------------------------------------------------------
# FDBSCAN with pair traversal (§4.2.3, improvement (7))
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("min_pts", "edge_capacity", "use_64bit"))
def fdbscan_pair(points: jax.Array, eps, min_pts: int,
                 edge_capacity: int = 8, use_64bit: bool = True) -> DbscanResult:
    """FDBSCAN whose union phase visits each unordered pair once.

    Each core query i captures up to ``edge_capacity`` CROSS-ROOT core
    neighbors j > i (in Morton order) and stops early when the buffer fills —
    the callback-side analogue of ECL-CC skipping same-root unions. The outer
    loop repeats while any buffer overflowed or labels changed; every
    overflowing round performs ≥1 merging union, so progress is guaranteed.
    """
    n = points.shape[0]
    lo, hi = _scene(points)
    bvh = build_bvh(points, lo, hi, use_64bit=use_64bit)

    core = _core_mask(bvh, points, eps, min_pts, early_stop=True)

    def capture(parent):
        # Engine pair backend: callback sees each unordered ε-pair once,
        # already distance-gated; carries come back in sorted query order.
        def fn(carry, i_orig, j_orig, _d2):
            buf, cnt = carry
            take = core[i_orig] & core[j_orig] & (parent[i_orig] != parent[j_orig])
            slot = jnp.clip(cnt, 0, edge_capacity - 1)
            buf = jnp.where(take, buf.at[slot].set(j_orig), buf)
            cnt = cnt + take.astype(jnp.int32)
            return (buf, cnt), cnt >= edge_capacity

        buf0 = jnp.full((edge_capacity,), -1, jnp.int32)
        return query(bvh, within(points, jnp.asarray(eps, points.dtype)),
                     fn, (buf0, jnp.int32(0)), backend="pair")

    def cond(state):
        _, changed, overflow, r = state
        return (changed | overflow) & (r < 64)

    def body(state):
        parent, _, _, r = state
        buf, cnt = capture(parent)
        overflow = jnp.any(cnt >= edge_capacity)
        # Buffer row k belongs to SORTED query k; its original id is leaf_perm[k].
        src = jnp.broadcast_to(bvh.leaf_perm[:, None], buf.shape)
        mask = buf >= 0
        parent2 = union_find.hook_min(parent, src.ravel(),
                                      jnp.clip(buf, 0, n - 1).ravel(), mask.ravel())
        parent2 = union_find.compress(parent2)
        return parent2, jnp.any(parent2 != parent), overflow, r + 1

    parent0 = jnp.arange(n, dtype=jnp.int32)
    parent, _, _, rounds = jax.lax.while_loop(
        cond, body, (parent0, jnp.bool_(True), jnp.bool_(True), jnp.int32(0)))
    parent = jnp.where(core, parent, jnp.arange(n, dtype=jnp.int32))

    border = _min_core_label_pass(bvh, points, eps, parent, core, ~core, n)
    labels = _finish_labels(parent, border, core, n)
    return DbscanResult(labels=labels, core_mask=core, num_rounds=rounds)


# ---------------------------------------------------------------------------
# FDBSCAN-DenseBox (§4.3.4)
# ---------------------------------------------------------------------------

def _seg_min(values_sorted: jax.Array, run_start: jax.Array) -> jax.Array:
    """Per-run min of values over the grid's cell runs (values in sorted order):
    forward min-scan restarted at run heads, then backward broadcast."""
    n = values_sorted.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_head = idx == run_start

    def fwd(a, b):
        # b overwrites if b is a head, else combine.
        val_a, head_a = a
        val_b, head_b = b
        return jnp.where(head_b, val_b, jnp.minimum(val_a, val_b)), head_a | head_b

    mins, _ = jax.lax.associative_scan(fwd, (values_sorted, is_head))
    # mins[t] = min over [run_start..t]; the run's min is mins at the run END
    # (run_start + run_length - 1), gathered by seg_min_per_point.
    return mins


def seg_min_per_point(values_sorted, run_start, run_length):
    mins = _seg_min(values_sorted, run_start)
    return mins[run_start + run_length - 1]


@partial(jax.jit, static_argnames=("min_pts", "use_64bit"))
def fdbscan_densebox(points: jax.Array, eps, min_pts: int,
                     use_64bit: bool = True) -> DbscanResult:
    """FDBSCAN-DenseBox (§4.3.4): mixed BVH over dense cells + loose points."""
    import math

    n, d = points.shape
    lo, hi = _scene(points)
    eps_f = jnp.asarray(eps, points.dtype)
    eps2 = eps_f ** 2
    grid = build_cell_grid(points, lo, hi, eps_f / math.sqrt(d))

    dense_s = grid.dense_mask_sorted(min_pts)          # per sorted point
    head_s = grid.is_run_head()
    pts_sorted = points[grid.perm]

    # --- Mixed leaf set in grid-sorted order (n fixed leaves): -------------
    #   dense head      -> the cell's box           (active "cell" leaf)
    #   dense non-head  -> its own point            (inactive; callback skips)
    #   loose point     -> its own point
    cell_lo, cell_hi = cell_box(grid, grid.cell_coord_sorted)
    leaf_is_cell = dense_s & head_s
    skip_leaf = dense_s & ~head_s
    leaf_lo = jnp.where(leaf_is_cell[:, None], cell_lo, pts_sorted)
    leaf_hi = jnp.where(leaf_is_cell[:, None], cell_hi, pts_sorted)
    bvh = build_bvh_objects(leaf_lo, leaf_hi, lo, hi, use_64bit=use_64bit)

    max_run = 1 << 20  # static bound for the inner cell scan

    def cell_scan(center, start, length, init, step):
        """Bounded loop over a cell's sorted points: step(carry, t) applied for
        t in [start, start+length)."""
        def body(state):
            t, carry = state
            carry = step(carry, t)
            return t + 1, carry

        def cond(state):
            t, carry = state
            return t < start + length

        _, out = jax.lax.while_loop(cond, body, (start, init))
        return out

    # --- Phase 1: core classification. Dense-cell points are core for free. --
    # Engine callback over the mixed tree: the predicate gate tests the leaf
    # VOLUME (cell box or point), so cells outside ε are skipped wholesale.
    def count_cb(count, qi, t, _d2):
        # qi = grid-sorted query index, t = grid-sorted object index. The
        # center gather is loop-invariant in qi; XLA's LICM hoists it out
        # of the traversal loop (timed: no cost vs the old vmap closure).
        center = pts_sorted[qi]

        def on_cell(count):
            # Whole cell within eps? add run_length wholesale.
            far2 = jnp.sum(jnp.maximum(jnp.abs(center - (cell_lo[t] + cell_hi[t]) * 0.5)
                                       + grid.cell_size * 0.5, 0.0) ** 2)
            whole = far2 <= eps2

            def scan_cell(c):
                def step(cc, u):
                    hit = jnp.sum((pts_sorted[u] - center) ** 2) <= eps2
                    return cc + hit.astype(jnp.int32)
                return cell_scan(center, grid.run_start[t], grid.run_length[t], c, step)

            return jnp.where(whole, count + grid.run_length[t], scan_cell(count))

        def on_point(count):
            hit = jnp.sum((pts_sorted[t] - center) ** 2) <= eps2
            return count + hit.astype(jnp.int32)

        count = jnp.where(
            skip_leaf[t], count,
            jnp.where(leaf_is_cell[t], on_cell(count), on_point(count)))
        return count, count >= min_pts

    # Queries only for loose (non-dense-cell) points, in grid-sorted order.
    counts_s = query(bvh, within(pts_sorted, eps_f), count_cb, jnp.int32(0))
    counts_s = jnp.where(~dense_s, counts_s, jnp.int32(0))
    core_s = dense_s | (counts_s >= min_pts)
    core = jnp.zeros(n, bool).at[grid.perm].set(core_s)

    # --- Phase 2: union rounds. Pre-union dense cells to their min member. --
    seg_min_orig = seg_min_per_point(grid.perm, grid.run_start, grid.run_length)
    # Dense-cell points are pre-unioned to the min original index in their cell;
    # scatter-min with own index elsewhere keeps identity.
    parent0 = jnp.arange(n, dtype=jnp.int32).at[grid.perm].min(
        jnp.where(dense_s, seg_min_orig, grid.perm))

    def min_label_pass(parent, queries_mask_s):
        # Per-cell current min label (for wholesale cell hits).
        cell_lab = seg_min_per_point(parent[grid.perm], grid.run_start, grid.run_length)

        def cb(best, qi, t, _d2):
            center = pts_sorted[qi]

            def on_cell(best):
                far2 = jnp.sum((jnp.maximum(jnp.abs(center - (cell_lo[t] + cell_hi[t]) * 0.5), 0.0)
                                + grid.cell_size * 0.5) ** 2)
                whole = far2 <= eps2

                def scan_cell(b):
                    def step(bb, u):
                        hit = jnp.sum((pts_sorted[u] - center) ** 2) <= eps2
                        return jnp.where(hit, jnp.minimum(bb, parent[grid.perm[u]]), bb)
                    return cell_scan(center, grid.run_start[t], grid.run_length[t], b, step)

                return jnp.where(whole, jnp.minimum(best, cell_lab[t]), scan_cell(best))

            def on_point(best):
                j = grid.perm[t]
                hit = (jnp.sum((pts_sorted[t] - center) ** 2) <= eps2) & core[j]
                return jnp.where(hit, jnp.minimum(best, parent[j]), best)

            best = jnp.where(
                skip_leaf[t], best,
                jnp.where(leaf_is_cell[t], on_cell(best), on_point(best)))
            return best, jnp.bool_(False)

        m_s = query(bvh, within(pts_sorted, eps_f), cb, jnp.int32(n))
        m_s = jnp.where(queries_mask_s, m_s, jnp.int32(n))
        return jnp.full(n, n, jnp.int32).at[grid.perm].min(m_s)

    # Union queries run from EVERY core point. A head-only representative
    # per dense cell under-merges: the one-directional min-label hook relies
    # on the pair being seen from BOTH endpoints' queries, and a loose point
    # within ε of a non-head member (but not of the head) is only seen from
    # its own side — if its label is the smaller one, the cell never adopts
    # it (regression caught by the Fig-4 ladder cross-check at n=512).
    # DenseBox's savings are preserved where they matter: dense members skip
    # the COUNT phase entirely and are pre-unioned, intra-cell pair tests
    # never happen, and whole-cell hits are processed wholesale.
    union_queries_s = core_s

    def cond(state):
        _, changed, r = state
        return changed & (r < 64)

    def body(state):
        parent, _, r = state
        m = min_label_pass(parent, union_queries_s)
        m = jnp.where(core, m, n)
        tgt = jnp.where(core, parent, n - 1)
        upd = jnp.where(core, jnp.minimum(m, parent), parent[tgt])
        parent2 = parent.at[tgt].min(upd)
        parent2 = union_find.compress(parent2)
        return parent2, jnp.any(parent2 != parent), r + 1

    parent, _, rounds = jax.lax.while_loop(
        cond, body, (union_find.compress(parent0), jnp.bool_(True), jnp.int32(0)))

    # --- Border pass for non-core points. ---
    border_s = min_label_pass(parent, ~core_s)
    border = border_s  # already scattered back to original order
    labels = _finish_labels(parent, border, core, n)
    return DbscanResult(labels=labels, core_mask=core, num_rounds=rounds)
