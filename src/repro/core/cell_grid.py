"""Regular ε-grid superimposed on the data (paper §4.3.4, Figure 9).

FDBSCAN-DenseBox superimposes a regular grid with cell length ε/√d so that
every cell's diameter is ≤ ε: a cell holding ≥ minPts points contains ONLY
core points and all intra-cell distance computations can be skipped ("dense"
cells, red in Fig. 9).

JAX-native representation: the grid is never materialized. Points are sorted
by linearized cell id; every cell is then a contiguous run in the sorted
order, and each point carries its run's ``[start, length)`` so callbacks can
iterate a cell's contents with a bounded loop. All arrays are fixed-shape.

The same structure (with cell length = ε) also backs the TPU-native tiled
FDBSCAN (`core/fdbscan_grid.py`), where the 3^d stencil of ε-cells replaces
BVH pruning.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CellGrid", "build_cell_grid", "cell_box"]


class CellGrid(NamedTuple):
    """Sorted-run grid structure over n points in d dims (fixed shapes)."""

    cell_size: jax.Array        # () float
    origin: jax.Array           # (d,) grid origin (scene lo)
    dims: jax.Array             # (d,) int32 cells per dimension
    perm: jax.Array             # (n,) int32: sorted position -> original index
    inv_perm: jax.Array         # (n,) int32: original index -> sorted position
    cell_id_sorted: jax.Array   # (n,) int32 linearized cell id per sorted point
    cell_coord_sorted: jax.Array  # (n, d) int32 cell coordinate per sorted point
    run_start: jax.Array        # (n,) int32 start of this point's cell run (sorted coords)
    run_length: jax.Array       # (n,) int32 number of points in this point's cell

    @property
    def num_points(self) -> int:
        return self.perm.shape[0]

    def dense_mask_sorted(self, min_pts: int) -> jax.Array:
        """True for sorted points living in a dense cell (run_length >= minPts)."""
        return self.run_length >= min_pts

    def is_run_head(self) -> jax.Array:
        """True for the first sorted point of each cell run."""
        return jnp.arange(self.num_points, dtype=jnp.int32) == self.run_start


def _linearize(coord: jax.Array, dims: jax.Array) -> jax.Array:
    """Row-major linear cell id; int32 is safe because callers bound dims so
    the product fits (tests + benches use <= ~2^30 cells)."""
    d = coord.shape[-1]
    lin = coord[..., 0]
    for k in range(1, d):
        lin = lin * dims[k] + coord[..., k]
    return lin


@partial(jax.jit, static_argnames=("max_dim_cells",))
def build_cell_grid(points: jax.Array, scene_lo: jax.Array, scene_hi: jax.Array,
                    cell_size: jax.Array, max_dim_cells: int = 1 << 30) -> CellGrid:
    """Bin (n, d) points into a regular grid with the given cell length.

    ``cell_size`` should be ε/√d for DenseBox (diameter ≤ ε) or ε for the
    stencil grid. Sorting is stable so the structure is deterministic.
    """
    n, d = points.shape
    cell_size = jnp.asarray(cell_size, points.dtype)
    extent = scene_hi - scene_lo
    dims = jnp.maximum(jnp.ceil(extent / cell_size).astype(jnp.int32), 1)
    dims = jnp.minimum(dims, max_dim_cells)

    coord = jnp.floor((points - scene_lo) / cell_size).astype(jnp.int32)
    coord = jnp.clip(coord, 0, dims - 1)
    lin = _linearize(coord, dims)

    perm = jnp.argsort(lin, stable=True).astype(jnp.int32)
    inv_perm = jnp.zeros(n, jnp.int32).at[perm].set(jnp.arange(n, dtype=jnp.int32))
    lin_sorted = lin[perm]
    coord_sorted = coord[perm]

    # Run structure: head positions via neighbor comparison + max-scan.
    idx = jnp.arange(n, dtype=jnp.int32)
    is_head = jnp.concatenate([jnp.ones(1, bool), lin_sorted[1:] != lin_sorted[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_head, idx, 0))
    # Run end (exclusive): reverse min-scan of head positions shifted.
    next_head = jnp.concatenate([jnp.where(is_head[1:], idx[1:], n), jnp.full(1, n, jnp.int32)])
    run_end = jax.lax.associative_scan(jnp.minimum, next_head, reverse=True)
    run_length = run_end - run_start

    return CellGrid(
        cell_size=cell_size,
        origin=scene_lo,
        dims=dims,
        perm=perm,
        inv_perm=inv_perm,
        cell_id_sorted=lin_sorted,
        cell_coord_sorted=coord_sorted,
        run_start=run_start,
        run_length=run_length,
    )


def cell_box(grid: CellGrid, coord: jax.Array) -> tuple[jax.Array, jax.Array]:
    """AABB of the grid cell at integer coordinate (d,) or (..., d)."""
    lo = grid.origin + coord.astype(grid.origin.dtype) * grid.cell_size
    return lo, lo + grid.cell_size
