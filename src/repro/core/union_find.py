"""Deterministic parallel union-find / connected components (paper §4.3, C8).

ArborX fuses an ECL-CC-style union-find (atomic CAS hooking) into traversal.
TPUs have no atomic CAS visible to XLA, so we use the other classic member of
the same family: **min-label hooking + pointer jumping** (Shiloach-Vishkin).
It is deterministic (scatter-min is order-independent), collective-friendly,
and converges in O(log n) hook/jump rounds on the forests produced here.

Two interfaces:
* ``connected_components(n, u, v, mask)`` — explicit edge list (the paper's
  pre-callback baseline, §4.3.1).
* ``hook_min`` / ``compress`` primitives — used by the fused FDBSCAN paths,
  where each round's candidate edges come straight from a traversal callback
  (never materialized globally).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress", "hook_min", "connected_components", "canonicalize"]


def compress(parent: jax.Array, rounds: int | None = None) -> jax.Array:
    """Full path compression: parent <- parent[parent] until fixpoint."""

    def cond(state):
        p, changed = state
        return changed

    def body(state):
        p, _ = state
        p2 = p[p]
        return p2, jnp.any(p2 != p)

    # peel one iteration so the carry types (incl. shard_map varying-manual
    # -axes, jax >= 0.8) are body-derived by construction
    p1 = parent[parent]
    changed0 = jnp.any(p1 != parent)
    parent, _ = jax.lax.while_loop(cond, body, (p1, changed0))
    return parent


def hook_min(parent: jax.Array, u: jax.Array, v: jax.Array, mask: jax.Array) -> jax.Array:
    """One deterministic hooking round: for every masked edge (u, v), hook the
    larger root under the smaller. Roots are approximated by current labels
    (callers interleave with ``compress``)."""
    n = parent.shape[0]
    pu = parent[u]
    pv = parent[v]
    lo = jnp.minimum(pu, pv)
    hi_ = jnp.maximum(pu, pv)
    lo = jnp.where(mask, lo, n)  # out-of-range min is a no-op via clip target
    hi_safe = jnp.where(mask, hi_, 0)
    # parent[hi] <- min(parent[hi], lo): scatter-min is deterministic.
    parent = parent.at[hi_safe].min(jnp.where(mask, lo, parent[hi_safe]))
    return parent


def connected_components(n: int, u: jax.Array, v: jax.Array,
                         mask: jax.Array | None = None) -> jax.Array:
    """Labels in [0, n): each vertex gets the min vertex id of its component."""
    if mask is None:
        mask = jnp.ones(u.shape, bool)
    parent0 = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        parent, _ = state
        p2 = hook_min(parent, u, v, mask)
        p2 = compress(p2)
        return p2, jnp.any(p2 != parent)

    # peel one iteration: carry types become body-derived (shard_map vma)
    first, changed0 = body((parent0, jnp.bool_(True)))
    parent, _ = jax.lax.while_loop(cond, body, (first, changed0))
    return parent


def canonicalize(labels: jax.Array) -> jax.Array:
    """Fully compress an arbitrary label-pointer array into root labels."""
    return compress(labels.astype(jnp.int32))
