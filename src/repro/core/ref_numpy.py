"""Pure-numpy DBSCAN oracle used by every correctness test.

Direct transliteration of Ester et al. (1996) semantics as restated in the
paper §3.1 (note: the ε-neighborhood INCLUDES the point itself, so an
isolated point has |N| = 1 and FOF is exactly minPts = 2):

* core:    |N_eps(x)| >= minPts
* cluster: connected components of the core-core ε-graph
* border:  non-core with >= 1 core ε-neighbor (joins one such cluster;
           which one is implementation-defined — tests compare cluster
           PARTITIONS on cores and membership-validity on borders)
* noise:   label -1

O(n^2); keep n small in tests.
"""
from __future__ import annotations

import numpy as np

NOISE = -1

__all__ = ["dbscan_ref", "NOISE", "core_mask_ref", "labels_equivalent",
           "halo_catalog_ref"]


def _neighbor_matrix(points: np.ndarray, eps: float) -> np.ndarray:
    # float32 end to end, matching the JAX tiers' comparison semantics
    # (points exactly at distance eps are knife-edge under any float order).
    pts = points.astype(np.float32)
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1, dtype=np.float32)
    return d2 <= np.float32(eps) ** 2


def core_mask_ref(points: np.ndarray, eps: float, min_pts: int) -> np.ndarray:
    return _neighbor_matrix(points, eps).sum(1) >= min_pts


def dbscan_ref(points: np.ndarray, eps: float, min_pts: int) -> np.ndarray:
    n = len(points)
    adj = _neighbor_matrix(points, eps)
    core = adj.sum(1) >= min_pts

    labels = np.full(n, NOISE, np.int64)
    # Union-find over core-core edges.
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    cu, cv = np.nonzero(adj & core[:, None] & core[None, :])
    for a, b in zip(cu, cv):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
    for i in range(n):
        if core[i]:
            labels[i] = find(i)
    # Border points: min core-neighbor root (deterministic choice).
    for i in range(n):
        if not core[i]:
            roots = [find(j) for j in np.nonzero(adj[i] & core)[0]]
            labels[i] = min(roots) if roots else NOISE
    return labels


def halo_catalog_ref(points: np.ndarray, velocities: np.ndarray,
                     labels: np.ndarray, capacity: int, min_count: int = 2,
                     particle_mass: float = 1.0) -> dict:
    """Pure-numpy oracle for ``halos.catalog.halo_catalog``.

    Mirrors the exact contract: provisional halos are label roots in
    ascending order, the first ``capacity`` of them are considered
    (``overflow`` flags surplus), halos with fewer than ``min_count``
    members are cut, survivors compact to slots 0..num_halos-1 keeping
    ascending-root order. Float sums in float32 to match device numerics.
    """
    points = np.asarray(points, np.float32)
    velocities = np.asarray(velocities, np.float32)
    labels = np.asarray(labels)
    roots_all = np.unique(labels[labels >= 0])
    overflow = len(roots_all) > capacity
    roots_prov = roots_all[:capacity]

    rows = []
    particle_halo = np.full(len(labels), -1, np.int64)
    for r in roots_prov:
        m = labels == r
        cnt = int(m.sum())
        if cnt < max(min_count, 1):
            continue
        x = points[m]
        v = velocities[m]
        center = x.sum(0, dtype=np.float32) / np.float32(cnt)
        vmean = v.sum(0, dtype=np.float32) / np.float32(cnt)
        ev2 = np.float32((v ** 2).sum(dtype=np.float32) / np.float32(cnt))
        vdisp = np.sqrt(max(ev2 - np.float32((vmean ** 2).sum()), 0.0))
        rmax = np.sqrt(((x - center) ** 2).sum(1).max()) if cnt else 0.0
        particle_halo[m] = len(rows)
        rows.append(dict(root=int(r), count=cnt,
                         mass=np.float32(cnt) * np.float32(particle_mass),
                         center=center, vmean=vmean, vdisp=np.float32(vdisp),
                         rmax=np.float32(rmax)))

    d = points.shape[1]
    out = {
        "num_halos": len(rows),
        "overflow": bool(overflow),
        "root": np.full(capacity, NOISE, np.int64),
        "count": np.zeros(capacity, np.int64),
        "mass": np.zeros(capacity, np.float32),
        "center": np.zeros((capacity, d), np.float32),
        "vmean": np.zeros((capacity, d), np.float32),
        "vdisp": np.zeros(capacity, np.float32),
        "rmax": np.zeros(capacity, np.float32),
        "particle_halo": particle_halo,
    }
    for k, row in enumerate(rows):
        for key in ("root", "count", "mass", "center", "vmean", "vdisp",
                    "rmax"):
            out[key][k] = row[key]
    return out


def labels_equivalent(a: np.ndarray, b: np.ndarray, core: np.ndarray,
                      adj_eps=None) -> bool:
    """Partition equality on CORE points + same noise set. Border points may
    legally differ between implementations (they join ANY adjacent cluster),
    so borders are only checked for 'joined a cluster at all'."""
    a = np.asarray(a)
    b = np.asarray(b)
    if ((a == NOISE) != (b == NOISE)).any():
        return False
    # Compare partitions restricted to core points.
    ca, cb = a[core], b[core]
    # map labels -> canonical ids by first occurrence
    def canon(x):
        _, inv = np.unique(x, return_inverse=True)
        first = {}
        out = np.empty(len(x), np.int64)
        k = 0
        for i, v in enumerate(inv):
            if v not in first:
                first[v] = k
                k += 1
            out[i] = first[v]
        return out

    return bool((canon(ca) == canon(cb)).all())
