"""Moving least squares interpolation (paper §3.2: "ArborX implements
moving least squares interpolation ... support and subsequently the
interpolation operator are constructed through solving local least squares
problems defined by compactly supported radial basis functions",
Quaranta et al. 2005).

For each target point: take the k nearest source points (the support, via
the kNN search), weight them with the compactly-supported Wendland C2 RBF
w(r) = (1 - r/R)^4 (4 r/R + 1) on the support radius R (the k-th neighbor
distance), and fit a local degree-1 polynomial by weighted least squares.
Reproduces linear fields exactly (the classic MLS consistency property,
tested)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.bvh import build_bvh
from repro.core.geometry import scene_bounds
from repro.core.query import nearest, query

__all__ = ["mls_interpolate", "wendland_c2"]


def wendland_c2(r: jax.Array, radius: jax.Array) -> jax.Array:
    t = jnp.clip(r / jnp.maximum(radius, 1e-12), 0.0, 1.0)
    return (1.0 - t) ** 4 * (4.0 * t + 1.0)


@partial(jax.jit, static_argnames=("k",))
def mls_interpolate(source_points: jax.Array, source_values: jax.Array,
                    targets: jax.Array, k: int = 8) -> jax.Array:
    """Interpolate scalar source_values (n,) onto targets (q, d)."""
    d = source_points.shape[1]
    assert k <= source_points.shape[0], (k, source_points.shape[0])
    lo, hi = scene_bounds(source_points)
    bvh = build_bvh(source_points, lo, hi)
    nn = query(bvh, nearest(targets, k))  # the engine's kNN protocol

    def one(target, idx, dist):
        pts = source_points[idx]                       # (k, d)
        vals = source_values[idx]                      # (k,)
        radius = 1.1 * jnp.max(dist) + 1e-12
        w = wendland_c2(dist, radius)                  # (k,)
        # degree-1 basis centered at the target (conditioning)
        basis = jnp.concatenate(
            [jnp.ones((idx.shape[0], 1)), pts - target], axis=1)  # (k, d+1)
        a = basis * w[:, None]
        gram = a.T @ basis + 1e-8 * jnp.eye(d + 1)
        rhs = a.T @ vals
        coef = jnp.linalg.solve(gram, rhs)
        return coef[0]                                 # value at the center

    return jax.vmap(one)(targets, nn.indices, nn.distances)
