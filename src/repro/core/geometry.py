"""Small geometric helpers shared by the BVH / grid / DBSCAN code."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Aabb", "aabb_of_points", "aabb_union", "point_aabb_dist2",
           "aabb_aabb_dist2", "scene_bounds"]


class Aabb(NamedTuple):
    lo: jax.Array  # (..., d)
    hi: jax.Array  # (..., d)


def aabb_of_points(points: jax.Array) -> Aabb:
    return Aabb(points.min(axis=0), points.max(axis=0))


def scene_bounds(points: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Scene AABB padded so degenerate extents keep Morton normalization
    well-defined (the bounds every BVH build in this repo wants)."""
    box = aabb_of_points(points)
    pad = jnp.maximum(1e-6, 1e-6 * jnp.max(box.hi - box.lo))
    return box.lo - pad, box.hi + pad


def aabb_union(a: Aabb, b: Aabb) -> Aabb:
    return Aabb(jnp.minimum(a.lo, b.lo), jnp.maximum(a.hi, b.hi))


def point_aabb_dist2(p: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Squared distance from point(s) to AABB(s); 0 if inside."""
    d = jnp.maximum(jnp.maximum(lo - p, p - hi), 0.0)
    return jnp.sum(d * d, axis=-1)


def aabb_aabb_dist2(lo_a: jax.Array, hi_a: jax.Array, lo_b: jax.Array, hi_b: jax.Array) -> jax.Array:
    """Squared distance between two AABBs; 0 if overlapping."""
    d = jnp.maximum(jnp.maximum(lo_b - hi_a, lo_a - hi_b), 0.0)
    return jnp.sum(d * d, axis=-1)
