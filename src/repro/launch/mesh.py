"""Production meshes. A FUNCTION (not a module-level constant) so importing
never touches jax device state."""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh across versions: axis_types only exists on newer JAX."""
    try:
        auto = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=auto)
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod: 16x16 = 256 chips ("data", "model"); multi-pod adds a leading
    "pod" axis (2 pods = 512 chips). "pod" composes with "data" for DP/FSDP."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """Single-process test mesh over whatever devices exist (1 on CPU)."""
    n = len(jax.devices())
    return _make_mesh((1, n), ("data", "model"))
