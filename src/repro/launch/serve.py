"""Serving driver: batched prefill + decode loop (deliverable (b)).

A minimal continuous-batching server core: requests arrive with prompts,
are prefillied into a shared KV cache, and decode in lock-step batches.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m --smoke \
      --requests 4 --gen-tokens 16
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch import steps
from repro.models import lm
from repro.models.spec import init_params


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="xlstm-350m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = init_params(lm.model_spec(cfg), jax.random.PRNGKey(args.seed),
                         jnp.float32 if args.smoke else jnp.bfloat16)

    rng = np.random.default_rng(args.seed)
    b, s = args.requests, args.prompt_len
    cache_len = s + args.gen_tokens
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.frontend_dim and not cfg.encoder_layers:
        batch["vision"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)

    prefill = jax.jit(functools.partial(steps.prefill_step, cfg=cfg,
                                        cache_len=cache_len))
    decode = jax.jit(functools.partial(steps.serve_step, cfg=cfg))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t1 = time.time()
    for i in range(args.gen_tokens - 1):
        tok, logits, cache = decode(params, cache, tok, jnp.int32(s + i))
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"prefill {b}x{s} tokens in {t_prefill:.2f}s; "
          f"decoded {args.gen_tokens - 1} steps in {t_decode:.2f}s "
          f"({b * (args.gen_tokens - 1) / max(t_decode, 1e-9):.1f} tok/s)")
    for r in range(min(b, 2)):
        print(f"request {r}: generated {gen[r].tolist()}")
    assert gen.shape == (b, args.gen_tokens)
    assert (gen >= 0).all() and (gen < cfg.vocab).all()


if __name__ == "__main__":
    main()
