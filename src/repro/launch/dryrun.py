"""Multi-pod dry-run (deliverable (e)) + roofline extraction (deliverable (g)).

For every (architecture x input shape x mesh) cell this:
  1. builds abstract inputs (ShapeDtypeStruct — zero allocation at any size),
  2. jit-lowers + compiles the step (train_step / prefill_step / serve_step)
     against the production mesh (16x16 single-pod or 2x16x16 multi-pod),
  3. records memory_analysis() (fits-in-HBM proof), cost_analysis() (FLOPs /
     bytes), and the collective-bytes breakdown parsed from the SPMD HLO,
  4. derives the three roofline terms against TPU v5e constants.

CLI:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs N]

Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""
from __future__ import annotations

# The 512 placeholder devices MUST be configured before jax initializes —
# first lines of the module, before any jax import (per the dry-run contract).
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import pathlib
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shapes_for
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch import steps
from repro.models import lm
from repro.models.spec import abstract_params, count_params
from repro.optim import adamw
from repro.parallel import sharding as shd

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# --- TPU v5e roofline constants (per chip) ----------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

COLLECTIVE_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64|f64)\[([\d,]*)\]")
DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic by op type, from result shapes.
    all-reduce counted 2x (reduce-scatter + all-gather equivalent)."""
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        if op == "all-reduce":
            b *= 2
        out[op] = out.get(op, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ---------------------------------------------------------------------------
# Abstract inputs per cell
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract model inputs for one cell (tokens/labels or decode state)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32),
                 "loss_mask": _sds((b, s), jnp.bool_)}
    elif shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
    else:  # decode: one token against an S-token cache
        batch = {"tokens": _sds((b, 1), jnp.int32)}
    if cfg.frontend_dim and not cfg.encoder_layers:
        batch["vision"] = _sds((b, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    if cfg.encoder_layers:
        batch["frames"] = _sds((b, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    return batch


def _abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, batch, cache_len))
    return jax.tree.map(lambda x: _sds(x.shape, x.dtype), cache)


def sharded_param_bytes(cfg: ModelConfig, mesh) -> float:
    """Exact per-device parameter bytes (bf16) under the sharding rules."""
    import jax.tree_util as jtu
    spec = lm.model_spec(cfg)
    leaves = jtu.tree_leaves(spec, is_leaf=lambda x: hasattr(x, "axes"))
    return sum(int(np.prod(l.shape)) * 2 / _shard_factor(l, mesh)
               for l in leaves)


def train_plan(cfg: ModelConfig, shape: ShapeConfig, mesh, sp: bool = False) -> dict:
    """Shared training-memory plan: gradient dtype and accumulation factor,
    derived from the exact sharded state footprint (used by build_cell AND
    memory_model so the dry-run measures what it models).

    * grads accumulate in bf16 when the f32 accumulator would push
      params+moments+grads past 12 GB/device (jamba-398B on one pod);
    * the scan-carry budget is what's left of HBM after state+slack.
    """
    params_b = sharded_param_bytes(cfg, mesh)
    state_f32g = params_b * (1 + 2 + 2)          # p + m/v bf16 + f32 grads
    grad_dtype = "bfloat16" if state_f32g > 12e9 else "float32"
    grad_b = params_b * (1 if grad_dtype == "bfloat16" else 2)
    state_b = params_b * 3 + grad_b
    carry_budget = float(np.clip(15e9 - state_b, 1e9, 4e9))

    sizes = dict(mesh.shape)
    dp = int(np.prod([sizes[a] for a in ("pod", "data") if a in sizes]))
    rows_total = max(shape.global_batch // dp, 1)
    carry_per_row = cfg.n_groups * shape.seq_len * cfg.d_model * 2
    if cfg.encoder_layers:  # enc-dec: encoder scan carries count too
        carry_per_row += cfg.encoder_layers * cfg.frontend_tokens * cfg.d_model * 2
    if any(k.startswith(("mlstm", "slstm")) for k in cfg.block_pattern):
        # xLSTM gate preactivations (4 per block) dominate the carry
        carry_per_row += 4 * shape.seq_len * cfg.n_heads * cfg.resolved_head_dim * 4
    if sp and shape.seq_len % sizes.get("model", 1) == 0:
        carry_per_row /= sizes.get("model", 1)  # seq-sharded saved carries
    rows = max(1, min(rows_total, int(carry_budget // max(carry_per_row, 1))))
    accum = 1
    while rows_total // accum > rows and rows_total % (accum * 2) == 0:
        accum *= 2
    return {"accum": accum, "rows": rows_total // accum,
            "grad_dtype": grad_dtype, "params_b": params_b,
            "carry_budget": carry_budget}


SP_MODE = False  # set by run_cell/diagnose; threads --sp into the plan


def accum_steps_for(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    return train_plan(cfg, shape, mesh, sp=SP_MODE)["accum"]


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (fn, args, in_shardings, out_shardings) ready to lower."""
    params = abstract_params(lm.model_spec(cfg), jnp.bfloat16)
    p_shard = shd.param_shardings(lm.model_spec(cfg), mesh)
    batch = input_specs(cfg, shape)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        plan = train_plan(cfg, shape, mesh, sp=SP_MODE)
        accum = plan["accum"]
        opt_cfg = adamw.OptConfig(accum_steps=accum,
                                  grad_dtype=plan["grad_dtype"])
        opt = adamw.abstract_opt_state(opt_cfg, params)
        o_shard = adamw.OptState(step=repl,
                                 m=jax.tree.map(lambda s: s, p_shard),
                                 v=jax.tree.map(lambda s: s, p_shard),
                                 error=None)
        state = steps.TrainState(params, opt)
        s_shard = steps.TrainState(p_shard, o_shard)

        if accum > 1:  # micro-batch leading axis: (accum, B/accum, ...)
            batch = jax.tree.map(
                lambda x: _sds((accum, x.shape[0] // accum) + x.shape[1:],
                               x.dtype), batch)
            b_shard = jax.tree.map(
                lambda x: NamedSharding(
                    mesh, P(None, *shd.data_pspec(mesh, x.shape[1],
                                                  len(x.shape) - 1))),
                batch)

            def fn(st, bt):
                return steps.train_step_accum(st, bt, cfg=cfg, opt_cfg=opt_cfg,
                                              param_shardings=p_shard)
        else:
            b_shard = jax.tree.map(
                lambda x: NamedSharding(mesh, shd.data_pspec(
                    mesh, x.shape[0], len(x.shape))), batch)

            def fn(st, bt):
                return steps.train_step(st, bt, cfg=cfg, opt_cfg=opt_cfg)

        # donate the train state: params/opt update in place (aliased)
        return fn, (state, batch), (s_shard, b_shard), (0,)

    if shape.kind == "prefill":
        b_shard = jax.tree.map(
            lambda x: NamedSharding(mesh, shd.data_pspec(
                mesh, x.shape[0], len(x.shape))), batch)

        def fn(p, bt):
            return steps.prefill_step(p, bt, cfg=cfg, cache_len=shape.seq_len)

        return fn, (params, batch), (p_shard, b_shard), ()

    # decode
    cache = _abstract_cache(cfg, shape.global_batch, shape.seq_len)
    c_shard = shd.cache_shardings(cache, mesh)
    token = batch["tokens"]
    t_shard = NamedSharding(mesh, shd.data_pspec(mesh, shape.global_batch, 2))
    pos = _sds((), jnp.int32)

    def fn(p, c, t, pp):
        return steps.serve_step(p, c, t, pp, cfg=cfg)

    # donate the cache: decode updates it in place (aliased in+out)
    return fn, (params, cache, token, pos), (p_shard, c_shard, t_shard, repl), (1,)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def _shard_factor(spec, mesh) -> int:
    sizes = dict(mesh.shape)
    pspec = shd.pspec_for(spec, mesh)
    f = 1
    for entry in pspec:
        if entry is None:
            continue
        for ax in ((entry,) if isinstance(entry, str) else entry):
            f *= sizes[ax]
    return f


def memory_model(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """Analytic per-device HBM model at TRUE dtypes (bf16 weights/activations,
    f32 where the program deliberately uses f32). Needed because the CPU
    backend's float normalization upcasts every bf16 dot to f32, so
    XLA buffer totals over-report by up to 2x vs the TPU target; the XLA
    number is reported alongside as an upper bound."""
    import jax.tree_util as jtu
    sizes = dict(mesh.shape)
    model = sizes.get("model", 1)
    dp = int(np.prod([sizes[a] for a in ("pod", "data") if a in sizes]))
    spec = lm.model_spec(cfg)
    leaves = jtu.tree_leaves(spec, is_leaf=lambda x: hasattr(x, "axes"))
    params_b = sum(int(np.prod(l.shape)) * 2 / _shard_factor(l, mesh)
                   for l in leaves)

    s, b = shape.seq_len, shape.global_batch
    d, hq = cfg.d_model, cfg.n_heads
    # score sharding mirror of default_score_pspec: heads over model when
    # divisible, else query-seq over model
    if hq % model == 0:
        h_loc, sq_div = hq / model, 1
    else:
        h_loc, sq_div = hq, model
    out: dict = {"params": params_b}

    if shape.kind == "train":
        plan = train_plan(cfg, shape, mesh, sp=SP_MODE)
        accum = plan["accum"]
        rows = max(b // dp // accum, 1)
        out["opt_moments"] = 2 * params_b               # bf16 m+v
        out["grads"] = params_b * (1 if plan["grad_dtype"] == "bfloat16" else 2)
        carry = cfg.n_groups * rows * s * d * 2
        if cfg.encoder_layers:
            carry += cfg.encoder_layers * rows * cfg.frontend_tokens * d * 2
        out["scan_carries"] = carry
        transients = []
        kinds = {k.removesuffix("_moe") for k in cfg.block_pattern}
        if kinds & {"attn", "attn_local", "cross"}:
            from repro.models.attention import CHUNKED_THRESHOLD, KV_CHUNK, Q_CHUNK
            if s >= CHUNKED_THRESHOLD:  # blockwise attention tiles
                transients.append(
                    2.5 * rows * h_loc * (Q_CHUNK / sq_div) * KV_CHUNK * 4)
            else:
                transients.append(2.5 * rows * h_loc * (s / sq_div) * s * 4)
        if cfg.is_moe:
            tg = min(cfg.moe_group_size, rows * s)
            g_loc = rows * s // tg
            cap = max(1, min(int(cfg.capacity_factor * tg * cfg.top_k
                                 / cfg.n_experts), tg))
            e_loc = max(cfg.n_experts // model, 1)
            disp = g_loc * tg * e_loc * cap * 2
            buf = g_loc * e_loc * cap * d * 2
            transients.append(2.5 * (2 * disp + 2 * buf))
        if "mamba" in kinds:
            di_loc = cfg.ssm_expand * d / model
            transients.append(
                3 * rows * cfg.ssm_chunk * di_loc * cfg.ssm_state * 4)
        if kinds & {"mlstm", "slstm"}:
            hd = cfg.resolved_head_dim
            transients.append(3 * rows * hq * max(cfg.ssm_chunk ** 2,
                                                  hd * hd) * 4)
            transients.append(4 * rows * s * hq * hd * 4)          # gate preacts
        pv = cfg.padded_vocab
        v_loc = pv / model if pv % model == 0 else pv
        transients.append(2 * rows * lm.LOSS_CHUNK * v_loc * 4)    # loss chunk
        out["transient_peak"] = max(transients)
    else:
        cache = jax.eval_shape(lambda: lm.init_cache(
            cfg, shape.global_batch, shape.seq_len))
        cache_b = 0.0
        for leaf in jax.tree.leaves(cache):
            pspec = shd.cache_pspec(mesh, tuple(leaf.shape))
            f = 1
            for entry in pspec:
                if entry is None:
                    continue
                for ax in ((entry,) if isinstance(entry, str) else entry):
                    f *= sizes[ax]
            cache_b += int(np.prod(leaf.shape)) * leaf.dtype.itemsize / f
        out["kv_cache"] = cache_b
        rows = max(b // dp, 1)
        if shape.kind == "prefill":
            out["activations"] = 4 * rows * s * d * 2
            if {k.removesuffix("_moe") for k in cfg.block_pattern} & \
                    {"attn", "attn_local", "cross"}:
                from repro.models.attention import (CHUNKED_THRESHOLD,
                                                    KV_CHUNK, Q_CHUNK)
                if s >= CHUNKED_THRESHOLD:
                    out["transient_peak"] = \
                        2 * rows * h_loc * (Q_CHUNK / sq_div) * KV_CHUNK * 4
                else:
                    out["transient_peak"] = 2 * rows * h_loc * (s / sq_div) * s * 4
        else:
            # decode: per-token scores (B, H, 1, S/model) f32 + output logits
            out["activations"] = 4 * rows * d * 2
            out["transient_peak"] = 2 * rows * hq * (s / model) * 4
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    out["fits_16GB"] = bool(out["total"] < 16e9)
    return {k: (float(v) if not isinstance(v, bool) else v)
            for k, v in out.items()}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic 'useful' FLOPs per device: 6·N_active·D (train) or 2·N_active·D
    (inference), D = global tokens, divided by chip count at report time."""
    n_total = count_params(lm.model_spec(cfg))
    if cfg.is_moe:
        # active = total - (inactive expert fraction of routed expert params)
        e, k = cfg.n_experts, cfg.top_k
        spec = lm.model_spec(cfg)
        import jax.tree_util as jtu
        routed = 0
        for path, leaf in jtu.tree_leaves_with_path(spec, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes")):
            if "moe" in jtu.keystr(path) and "shared" not in jtu.keystr(path) \
                    and "router" not in jtu.keystr(path):
                routed += int(np.prod(leaf.shape))
        n_active = n_total - routed * (1 - k / e)
    else:
        n_active = n_total
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * tokens


def roofline(cost: dict, coll: dict, n_chips: int, cfg, shape) -> dict:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(coll.get("total", 0))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"), (t_coll, "collective"))[1]
    mf = model_flops(cfg, shape) / n_chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_dev,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": mf / flops_dev if flops_dev else None,
        "step_time_bound_s": max(t_compute, t_memory, t_coll),
        "mfu_bound": mf / PEAK_FLOPS / max(t_compute, t_memory, t_coll)
        if max(t_compute, t_memory, t_coll) > 0 else None,
    }


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def _set_constraints(mesh, shape: ShapeConfig, sp: bool,
                     cfg: ModelConfig | None = None):
    global SP_MODE
    SP_MODE = sp
    """Score sharding is ALWAYS pinned for non-decode shapes: GSPMD cannot
    propagate head-sharding through the GQA broadcast+reshape, and
    unconstrained (B, H, S, S) scores replicate (~43GB/layer at 4k).
    The Megatron-SP pair (seq-sharded residuals + gathered attention inputs)
    is the optional --sp experiment."""
    if shape.kind != "decode":
        shd.set_score_pspec(shd.default_score_pspec(
            mesh, cfg.n_heads if cfg is not None else None))
        shd.set_block_input_pspec(shd.default_attn_input_pspec(mesh))
        shd.set_decode_score_pspec(None)
    else:
        shd.set_score_pspec(None)
        shd.set_block_input_pspec(None)
        # flash-decode: scores sharded over KV-seq; never gather the cache
        shd.set_decode_score_pspec(shd.decode_score_pspec(mesh))
    if sp and shape.kind != "decode":
        seq_ok = shape.seq_len % dict(mesh.shape).get("model", 1) == 0
        shd.set_activation_pspec(shd.default_activation_pspec(mesh, seq_ok))
        shd.set_attn_input_pspec(shd.default_attn_input_pspec(mesh))
    else:
        shd.set_activation_pspec(None)
        shd.set_attn_input_pspec(None)


OP_LINE_RE = re.compile(r"^\s+%?[\w.\-]+ = ")
SKIP_OPS = re.compile(r"\b(parameter|constant|get-tuple-element|tuple|bitcast"
                      r"|copy-start|copy-done)\(")


def hlo_traffic_bytes(hlo_text: str) -> float:
    """True-dtype HBM-traffic proxy: sum of op OUTPUT bytes x2 (read+write
    amortized), skipping no-op/aliasing ops. XLA's own 'bytes accessed' is
    unusable here: the CPU backend's float normalization upcasts every bf16
    dot to f32 first (2x inflation that would not exist on TPU)."""
    total = 0
    for line in hlo_text.splitlines():
        if not OP_LINE_RE.match(line) or SKIP_OPS.search(line):
            continue
        head = line.split("=", 1)[1].lstrip()
        shape_txt = head.split(" ", 1)[0]
        total += _shape_bytes(shape_txt)
    return float(total * 2)


def _spmd_hlo(lowered, compiled_dir: str) -> str:
    """Read the after-spmd-partitioning HLO (true dtypes) from the dump."""
    import glob
    cands = sorted(glob.glob(os.path.join(compiled_dir,
                                          "*after_spmd-partitioning*.txt")))
    if not cands:
        raise RuntimeError(f"no spmd dump in {compiled_dir}")
    return open(cands[-1]).read()


def _lower_compile(cfg, shape, mesh):
    """Compile once (rolled scans = production GSPMD decisions); cost terms
    come from the loop-aware HLO walker over the post-SPMD dump (true
    dtypes, while bodies multiplied by their trip counts)."""
    import tempfile
    from repro.launch.hlo_cost import analyze_hlo
    fn, args, in_sh, donate = build_cell(cfg, shape, mesh)
    dump_dir = tempfile.mkdtemp(prefix="dryrun_hlo_")
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh,
                         donate_argnums=donate if donate else ())
        lowered = jitted.lower(*args)
        compiled = lowered.compile(compiler_options={
            "xla_dump_to": dump_dir,
            "xla_dump_hlo_pass_re": "spmd-partitioning",
        })
        ca = compiled.cost_analysis()
        # older JAX returns [dict] (one entry per device assignment)
        cost = dict(ca[0] if isinstance(ca, (list, tuple)) else ca)
        mem = compiled.memory_analysis()
    hlo = _spmd_hlo(lowered, dump_dir)
    import shutil
    shutil.rmtree(dump_dir, ignore_errors=True)
    walked = analyze_hlo(hlo)
    metrics = {
        "flops": walked["flops"],
        "bytes": walked["traffic"],
        "coll": walked["coll"],
        "xla_flops_uncorrected": float(cost.get("flops", 0.0)),
    }
    return metrics, mem, hlo


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: pathlib.Path,
             activation_sharding: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = int(np.prod(mesh.devices.shape))
    sp = activation_sharding or (cfg.prefer_sp and shape.kind == "train")
    _set_constraints(mesh, shape, sp, cfg)

    # full-depth rolled compile: memory + compile sanity + loop-aware costs.
    t0 = time.time()
    rolled, mem, hlo = _lower_compile(cfg, shape, mesh)
    t_full = time.time() - t0
    _set_constraints(mesh, shape, False)

    cost = {"flops": rolled["flops"], "bytes accessed": rolled["bytes"]}
    coll = dict(rolled["coll"])

    mem_total = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    mm = memory_model(cfg, shape, mesh)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": f"{mesh.devices.shape}",
        "n_chips": n_chips,
        "kind": shape.kind,
        "full_compile_s": round(t_full, 1),
        "xla_flops_uncorrected": rolled["xla_flops_uncorrected"],
        "memory": {
            # XLA CPU buffer totals: UPPER BOUND (float normalization runs
            # every bf16 dot in f32 on this backend; TPU keeps bf16).
            "xla_cpu_upper_bound": mem_total,
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            # analytic true-dtype model (see memory_model docstring):
            "model": mm,
            "total_per_dev": mm["total"],
            "fits_16GB": mm["fits_16GB"],
        },
        "cost_rolled": rolled,
        "collectives": coll,
        "roofline": roofline(cost, coll, n_chips, cfg, shape),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    out_path.write_text(json.dumps(result, indent=2))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--out", default=str(RESULTS_DIR))
    # Megatron-SP experiment knob (hillclimb lever; measured slower+bigger
    # under GSPMD on these models — see EXPERIMENTS.md §Perf):
    ap.add_argument("--sp", action="store_true",
                    help="seq-shard activations + constrain scores")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)

    if args.all:
        # Orchestrate subprocesses (each needs its own XLA device-count init).
        import subprocess
        cells = []
        for arch in ARCH_IDS:
            for shape in shapes_for(get_config(arch)):
                for mesh in (("single", "multi") if args.mesh == "both" else (args.mesh,)):
                    target = out_dir / f"{arch}__{shape.name}__{mesh}.json"
                    if not target.exists():
                        cells.append((arch, shape.name, mesh))
        print(f"{len(cells)} cells to run")
        running: list[tuple[subprocess.Popen, tuple]] = []
        failures = []
        while cells or running:
            while cells and len(running) < args.jobs:
                cell = cells.pop(0)
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", cell[0], "--shape", cell[1], "--mesh", cell[2],
                       "--out", str(out_dir)]
                if args.sp:
                    cmd.append("--sp")
                running.append((subprocess.Popen(cmd), cell))
            done = [(p, c) for p, c in running if p.poll() is not None]
            running = [(p, c) for p, c in running if p.poll() is None]
            for p, c in done:
                status = "ok" if p.returncode == 0 else f"FAIL rc={p.returncode}"
                print(f"[{time.strftime('%H:%M:%S')}] {c} -> {status}", flush=True)
                if p.returncode != 0:
                    failures.append(c)
            time.sleep(2)
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    res = run_cell(args.arch, args.shape, args.mesh, out_dir,
                   activation_sharding=args.sp)
    r = res["roofline"]
    print(json.dumps({
        "cell": f"{args.arch} x {args.shape} x {args.mesh}",
        "fits": res["memory"]["fits_16GB"],
        "mem_GB": round(res["memory"]["total_per_dev"] / 1e9, 2),
        "dominant": r["dominant"],
        "t_compute_ms": round(r["t_compute_s"] * 1e3, 3),
        "t_memory_ms": round(r["t_memory_s"] * 1e3, 3),
        "t_collective_ms": round(r["t_collective_s"] * 1e3, 3),
    }, indent=2))


if __name__ == "__main__":
    main()
