"""Per-cell perf diagnostics for the hillclimb loop:

  PYTHONPATH=src python -m repro.launch.diagnose --arch qwen3-moe-235b-a22b \
      --shape train_4k [--mesh single] [--sp]

Prints the memory-model breakdown and the top loop-multiplied collectives
(the dry-run "profile" — DESIGN.md §6.5 / Pallas hints: the profile is the
lowered IR, not a wall-clock trace).
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json

import numpy as np


def main() -> None:
    from repro.configs import ARCH_IDS, SHAPES, get_config
    from repro.launch import dryrun as dr
    from repro.launch.hlo_cost import analyze_hlo
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=tuple(SHAPES), required=True)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    dr._set_constraints(mesh, shape, args.sp, cfg)
    _, mem, hlo = dr._lower_compile(cfg, shape, mesh)
    dr._set_constraints(mesh, shape, False)

    res = analyze_hlo(hlo, top_k=args.top)
    mm = dr.memory_model(cfg, shape, mesh)
    print("memory model (GB):", json.dumps(
        {k: round(v / 1e9, 3) if isinstance(v, float) else v
         for k, v in mm.items()}, indent=1))
    print(f"flops/dev: {res['flops'] / 1e12:.1f} T   "
          f"traffic/dev: {res['traffic'] / 1e9:.1f} GB   "
          f"collectives/dev: {res['coll']['total'] / 1e9:.1f} GB")
    print("top collectives (loop-multiplied, per device):")
    for item in res["top_collectives"]:
        print(f"  {item['gbytes']:9.2f} GB  {item['op']:19s} {item['shape']}")


if __name__ == "__main__":
    main()
