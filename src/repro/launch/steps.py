"""Jittable step functions: train_step (fwd + bwd + AdamW) and serve steps
(prefill_step / decode one token). These are what the dry-run lowers and the
real launcher runs."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState


def train_step(state: TrainState, batch: dict, *, cfg: ModelConfig,
               opt_cfg: adamw.OptConfig):
    """One optimizer step (grad accumulation handled by the caller looping
    micro-batches; accum_steps=1 here keeps the dry-run graph canonical)."""

    def loss_fn(params):
        loss, metrics = lm.train_loss(params, cfg, batch)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
    new_params, new_opt, opt_metrics = adamw.apply_updates(
        opt_cfg, state.params, grads, state.opt)
    metrics = dict(metrics, **opt_metrics, total_loss=loss)
    return TrainState(new_params, new_opt), metrics


def train_step_accum(state: TrainState, batches: dict, *, cfg: ModelConfig,
                     opt_cfg: adamw.OptConfig, param_shardings=None):
    """Gradient accumulation over a leading micro-batch axis in ``batches``.

    ``param_shardings`` pins the f32 accumulator tree to the parameter
    layout — without it GSPMD can replicate the accumulator (a full f32
    param copy per device)."""

    def loss_fn(params, batch):
        loss, _ = lm.train_loss(params, cfg, batch)
        return loss

    def constrain(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_shardings)

    def micro(carry, batch):
        gsum, lsum = carry
        loss, g = jax.value_and_grad(loss_fn)(state.params, batch)
        gsum = constrain(jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                      gsum, g))
        return (gsum, lsum + loss), None

    from repro.models import runtime_flags as rf
    gdt = jnp.bfloat16 if opt_cfg.grad_dtype == "bfloat16" else jnp.float32
    zeros = constrain(jax.tree.map(lambda p: jnp.zeros(p.shape, gdt),
                                   state.params))
    (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)), batches,
                                   unroll=rf.scan_unroll(opt_cfg.accum_steps))
    n = opt_cfg.accum_steps
    grads = jax.tree.map(lambda g: (g / n).astype(jnp.float32), gsum)
    new_params, new_opt, om = adamw.apply_updates(opt_cfg, state.params, grads, state.opt)
    return TrainState(new_params, new_opt), dict(om, total_loss=lsum / n)


def prefill_step(params, batch: dict, *, cfg: ModelConfig, cache_len: int):
    logits, cache = lm.prefill(params, cfg, batch, cache_len=cache_len)
    return logits, cache


def serve_step(params, cache, token: jax.Array, cache_pos: jax.Array, *,
               cfg: ModelConfig):
    """One new token against an existing KV cache / recurrent state."""
    logits, new_cache = lm.decode_step(params, cfg, token, cache, cache_pos)
    next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    return next_token, logits, new_cache
