"""End-to-end training driver (deliverable (b): the e2e example).

Runs a real training loop — synthetic deterministic data, AdamW, async
checkpointing, straggler watchdog, in-situ DBSCAN analysis at the HACC
cadence — on whatever devices exist (CPU host mesh for the container,
the production mesh on real hardware).

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import json
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.insitu import InsituAnalyzer, InsituConfig
from repro.checkpoint.store import CheckpointStore
from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch import steps
from repro.models import lm
from repro.models.spec import init_params
from repro.optim import adamw
from repro.runtime.supervisor import Supervisor, SupervisorConfig


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="xlstm-350m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--insitu-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    opt_cfg = adamw.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                              total_steps=args.steps, moment_dtype="float32")

    data = SyntheticTokens(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
        frontend_tokens=cfg.frontend_tokens, frontend_dim=cfg.frontend_dim))

    def init_state():
        params = init_params(lm.model_spec(cfg), jax.random.PRNGKey(args.seed),
                             jnp.float32 if args.smoke else jnp.bfloat16)
        return steps.TrainState(params, adamw.init_opt_state(opt_cfg, params))

    jit_step = jax.jit(functools.partial(steps.train_step, cfg=cfg,
                                         opt_cfg=opt_cfg))
    analyzer = InsituAnalyzer(InsituConfig(cadence=args.insitu_every))
    store = CheckpointStore(args.ckpt_dir)
    losses: list[float] = []

    def step_fn(state, step):
        batch = data.batch_at(step)
        state, metrics = jit_step(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        insitu = analyzer.maybe_run(state.params, step)
        if insitu:
            print(f"step {step:5d} insitu {json.dumps(insitu)}", flush=True)
        return state, metrics

    sup = Supervisor(SupervisorConfig(total_steps=args.steps,
                                      checkpoint_every=args.ckpt_every),
                     store)
    t0 = time.time()
    state = sup.run(init_state_fn=init_state, step_fn=step_fn)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s); "
          f"first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
