"""HLO cost walker: loop-aware FLOPs / traffic / collective accounting.

XLA's ``cost_analysis()`` counts each while-loop body ONCE (no trip-count
multiplication), which undercounts scanned-layer models by ~n_layers x.
This walker parses the post-SPMD HLO text (true dtypes, production GSPMD
decisions — the CPU backend's f32 normalization has not run yet), builds
the computation call graph, extracts while trip counts from the loop
condition, and accumulates per-device costs bottom-up:

  flops      — dot ops: 2 * prod(output shape) * contraction size
               (contraction read from lhs_contracting_dims + operand shape)
  coll_bytes — by collective type; result-shape bytes (all-reduce x2)
  traffic    — HBM proxy: dot operands+outputs, DUS/gather/scatter/reduce
               in+out, collective results (elementwise ops are assumed fused)

Trip counts: scan lowers to while with a trip counter compared against a
constant; we find `compare(gte, constant(N)) direction=LT` in the condition
computation. Unrecognized conditions get multiplier 1 (and are reported).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
               "u16": 2, "c64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

SHAPE_RE = re.compile(r"(" + "|".join(DTYPE_BYTES) + r")\[([\d,]*)\]")
DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],]+(?:\{[^}]*\})?))\s+([\w\-]+)\(")
# computation headers sit at column 0: `%name (params...) -> type {`
COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
CALL_RE = re.compile(r"(?:calls|to_apply|condition|body|branch_computations)="
                     r"(?:\{([^}]*)\}|%?([\w.\-]+))")
CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*s32\[\]\s+constant\((\d+)\)")
COMPARE_RE = re.compile(r"compare\(([^)]*)\),?.*direction=(LT|LE|GT|GE|NE)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * DTYPE_BYTES[dt]
    return elems, total


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    traffic: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    # (op, shape_text) -> bytes, loop-multiplied — hillclimb diagnostics
    detail: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.traffic += other.traffic * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult
        for k, v in other.detail.items():
            self.detail[k] += v * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[str]] = {}
        self.shapes: dict[str, str] = {}      # op name -> shape text
        cur = None
        self.entry: str | None = None
        for line in text.splitlines():
            m = COMP_RE.match(line) if not line[:1].isspace() else None
            if m and " = " not in line.split("->")[0]:
                cur = m.group(1)
                self.computations[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                self.computations[cur].append(line)
                d = DEF_RE.match(line)
                if d:
                    self.shapes[d.group(1)] = d.group(2)

    # --- trip counts ----------------------------------------------------------

    def trip_count(self, cond_comp: str) -> int | None:
        lines = self.computations.get(cond_comp, [])
        consts = {}
        for ln in lines:
            c = CONST_RE.search(ln)
            if c:
                consts[c.group(1)] = int(c.group(2))
        for ln in lines:
            m = COMPARE_RE.search(ln)
            if m:
                args = [a.strip().lstrip("%") for a in m.group(1).split(",")]
                for a in args:
                    if a in consts:
                        n = consts[a]
                        return n + 1 if m.group(2) == "LE" else n
        return None

    # --- per-op costs -----------------------------------------------------------

    def _operand_names(self, line: str) -> list[str]:
        m = re.search(r"\(([^)]*)\)", line.split("=", 1)[1])
        if not m:
            return []
        return [a.strip().lstrip("%") for a in m.group(1).split(",") if a.strip()]

    def _dot_flops(self, line: str, out_shape: str) -> float:
        out_elems, _ = _shape_elems_bytes(out_shape)
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        ops = self._operand_names(line)
        if not mc or not ops:
            return 0.0
        lhs_shape = self.shapes.get(ops[0], "")
        dims_m = SHAPE_RE.search(lhs_shape)
        if not dims_m:
            return 0.0
        dims = [int(x) for x in dims_m.group(2).split(",")] if dims_m.group(2) else []
        k = 1
        for ci in (int(x) for x in mc.group(1).split(",") if x):
            if ci < len(dims):
                k *= dims[ci]
        return 2.0 * out_elems * k

    def _line_costs(self, line: str, comp_costs: dict) -> Costs:
        c = Costs()
        d = DEF_RE.match(line)
        if not d:
            return c
        shape_txt, op = d.group(2), d.group(3)
        _, out_bytes = _shape_elems_bytes(shape_txt)

        # recurse into called computations
        for m in CALL_RE.finditer(line):
            names = ([n.strip().lstrip("%") for n in m.group(1).split(",")]
                     if m.group(1) else [m.group(2)])
            if op == "while":
                cond, body = None, None
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                bm = re.search(r"body=%?([\w.\-]+)", line)
                if cm and bm:
                    cond, body = cm.group(1), bm.group(1)
                    trips = self.trip_count(cond) or 1
                    c.add(comp_costs[body], trips)
                break
            for nm in names:
                if nm in comp_costs and op != "while":
                    c.add(comp_costs[nm])

        if op == "dot":
            c.flops += self._dot_flops(line, shape_txt)
            in_bytes = sum(_shape_elems_bytes(self.shapes.get(o, ""))[1]
                           for o in self._operand_names(line))
            c.traffic += out_bytes + in_bytes
            c.detail[("traffic:dot", shape_txt)] += out_bytes + in_bytes
        elif op in COLLECTIVES or any(op == f"{k}-start" for k in COLLECTIVES):
            base = op.removesuffix("-start")
            bytes_ = out_bytes * (2 if base == "all-reduce" else 1)
            c.coll[base] += bytes_
            c.detail[(base, shape_txt)] += bytes_
            c.traffic += out_bytes
        elif op in ("dynamic-slice", "gather"):
            # reads only the sliced region (~= output), not the whole buffer
            c.traffic += 2 * out_bytes
        elif op == "dynamic-update-slice":
            ops_ = self._operand_names(line)
            upd = _shape_elems_bytes(self.shapes.get(ops_[1], ""))[1] \
                if len(ops_) > 1 else out_bytes
            c.traffic += 2 * upd  # in-place: read update + write region
        elif op == "scatter":
            ops_ = self._operand_names(line)
            upd = _shape_elems_bytes(self.shapes.get(ops_[-1], ""))[1] \
                if ops_ else out_bytes
            c.traffic += 2 * upd
        elif op in ("reduce", "reduce-window", "sort", "convolution",
                    "cholesky", "triangular-solve"):
            in_bytes = sum(_shape_elems_bytes(self.shapes.get(o, ""))[1]
                           for o in self._operand_names(line))
            c.traffic += out_bytes + in_bytes
            c.detail[(f"traffic:{op}", shape_txt)] += out_bytes + in_bytes
        return c

    def entry_costs(self, entry: str | None = None) -> Costs:
        # bottom-up: process computations in dependency order (iteratively)
        comp_costs: dict[str, Costs] = {}
        remaining = dict(self.computations)
        for _ in range(len(remaining) + 2):
            progressed = False
            for name, lines in list(remaining.items()):
                deps = set()
                for ln in lines:
                    for m in CALL_RE.finditer(ln):
                        names = ([n.strip().lstrip("%") for n in m.group(1).split(",")]
                                 if m.group(1) else [m.group(2)])
                        deps.update(n for n in names if n in self.computations)
                if deps - set(comp_costs):
                    continue
                total = Costs()
                for ln in lines:
                    total.add(self._line_costs(ln, comp_costs))
                comp_costs[name] = total
                del remaining[name]
                progressed = True
            if not remaining or not progressed:
                break
        if entry is None:
            entry = self.entry
        if entry is None:
            # fallback: a computation never referenced by others
            referenced = set()
            for lines in self.computations.values():
                for ln in lines:
                    for m in CALL_RE.finditer(ln):
                        names = ([n.strip().lstrip("%") for n in m.group(1).split(",")]
                                 if m.group(1) else [m.group(2)])
                        referenced.update(names)
            entries = [n for n in self.computations if n not in referenced]
            entry = entries[0] if entries else next(iter(self.computations))
        if entry not in comp_costs:
            raise RuntimeError(
                f"HLO walker failed to resolve entry {entry!r}; "
                f"unresolved computations: {len(self.computations) - len(comp_costs)}")
        return comp_costs[entry]


def analyze_hlo(text: str, top_k: int = 0) -> dict:
    mod = HloModule(text)
    c = mod.entry_costs()
    coll = dict(c.coll)
    coll["total"] = sum(coll.values())
    out = {"flops": c.flops, "traffic": c.traffic, "coll": coll}
    if top_k:
        items = sorted(c.detail.items(), key=lambda kv: -kv[1])[:top_k]
        out["top_collectives"] = [
            {"op": op, "shape": shp, "gbytes": round(b / 1e9, 3)}
            for (op, shp), b in items]
    return out
