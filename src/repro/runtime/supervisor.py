"""Fault-tolerant training runtime (DESIGN.md §6).

The supervisor owns the train loop: periodic async checkpoints, automatic
restart from the last committed step after a failure, straggler detection,
and an injectable fault hook used by the tests (the moral equivalent of
pulling a node).

At 1000+-node scale the same structure runs per-host under a cluster
scheduler: any fatal error -> process exits nonzero -> scheduler restarts
the job -> ``run()`` resumes from the newest committed checkpoint (possibly
on a different mesh shape — restore re-shards; see checkpoint/store.py).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore

log = logging.getLogger("repro.supervisor")


@dataclasses.dataclass
class SupervisorConfig:
    total_steps: int
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    max_restarts: int = 10
    # straggler watchdog: flag steps slower than ewma * threshold
    straggler_threshold: float = 2.5
    ewma_alpha: float = 0.1


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    is_straggler: bool
    metrics: dict


class StragglerWatchdog:
    """Per-step wall-clock EWMA; flags outliers (the single-process analogue
    of cross-host slow-rank detection — on a real cluster the same EWMA is
    fed from per-host step barriers)."""

    def __init__(self, threshold: float, alpha: float):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: float | None = None
        self.flagged: list[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        if self.ewma is None:
            self.ewma = seconds
            return False
        is_slow = seconds > self.threshold * self.ewma
        if is_slow:
            self.flagged.append(step)
            log.warning("straggler step %d: %.3fs vs ewma %.3fs",
                        step, seconds, self.ewma)
        # slow steps don't poison the baseline
        if not is_slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return is_slow


class Supervisor:
    def __init__(self, cfg: SupervisorConfig, store: CheckpointStore):
        self.cfg = cfg
        self.store = store
        self.stats: list[StepStats] = []
        self.restarts = 0

    def run(self, *, init_state_fn: Callable[[], Any],
            step_fn: Callable[[Any, int], tuple[Any, dict]],
            state_shardings=None,
            fault_hook: Callable[[int], None] | None = None) -> Any:
        """Run to total_steps with restart-on-failure.

        init_state_fn: builds fresh state (step 0).
        step_fn(state, step) -> (state, metrics) — one optimizer step.
        fault_hook(step): test hook; may raise to simulate a node failure.
        """
        watchdog = StragglerWatchdog(self.cfg.straggler_threshold,
                                     self.cfg.ewma_alpha)
        while True:
            try:
                state, start = self._restore_or_init(init_state_fn, state_shardings)
                for step in range(start, self.cfg.total_steps):
                    t0 = time.time()
                    if fault_hook is not None:
                        fault_hook(step)
                    state, metrics = step_fn(state, step)
                    jax.block_until_ready(jax.tree.leaves(metrics)[0])
                    dt = time.time() - t0
                    slow = watchdog.observe(step, dt)
                    self.stats.append(StepStats(step, dt, slow, jax.tree.map(
                        lambda x: float(np.asarray(x)), metrics)))
                    next_step = step + 1
                    if next_step % self.cfg.checkpoint_every == 0:
                        self.store.save_async(next_step, state)
                        self.store.prune(self.cfg.keep_checkpoints)
                self.store.wait()
                self.store.save(self.cfg.total_steps, state)
                return state
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — restart-on-anything
                self.restarts += 1
                log.error("step failure (%s); restart %d/%d", e,
                          self.restarts, self.cfg.max_restarts)
                self.store.wait()
                if self.restarts > self.cfg.max_restarts:
                    raise

    def _restore_or_init(self, init_state_fn, state_shardings):
        template = jax.eval_shape(init_state_fn)
        latest = self.store.latest_step()
        if latest is None:
            return init_state_fn(), 0
        state, step = self.store.restore(template, latest, state_shardings)
        log.info("restored step %d", step)
        return state, step
