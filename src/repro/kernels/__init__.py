"""Pallas TPU kernels for the paper's compute hot spots — ε-neighborhood
queries with fused callbacks (DESIGN.md §2): `pairwise.py` (pl.pallas_call
+ BlockSpec kernels), `segment.py` (segmented reductions over sorted halo
ids, the catalog hot loop), `ops.py` (jit'd padded wrappers), `ref.py`
(pure-jnp oracles for the allclose sweeps in tests/test_kernels.py and
tests/test_halos.py)."""
from repro.kernels import ops, ref, segment, wavefront

__all__ = ["ops", "ref", "segment", "wavefront"]
