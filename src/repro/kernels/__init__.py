"""Pallas TPU kernels for the paper's compute hot spot — ε-neighborhood
queries with fused callbacks (DESIGN.md §2): `pairwise.py` (pl.pallas_call
+ BlockSpec kernels), `ops.py` (jit'd padded wrappers), `ref.py` (pure-jnp
oracles for the allclose sweeps in tests/test_kernels.py)."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
