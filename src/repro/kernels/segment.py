"""Pallas TPU segmented reductions over SORTED segments (halo catalogs).

The halo-catalog hot loop (labels -> per-halo sums) is a segmented reduction:
``out[s] = reduce(data[i] for i where seg_ids[i] == s)``. XLA lowers
``.at[seg].add`` to a serial scatter on TPU; here the bulk of the work is
reformulated as *tiled one-hot matmuls* on the MXU (the same trick that made
the ε-neighborhood kernels in ``pairwise.py`` TPU-native):

1. rows are processed in tiles of ``T`` sorted rows;
2. each tile builds a (T, 2T) one-hot matrix of its rows' segment ids
   RELATIVE to the tile's T-aligned base segment, and contracts it against the
   (T, D) data tile on the MXU -> a (2T, D) aligned partial;
3. partials land in T-aligned windows of the output, so the final combine is
   a scatter-add of ``n/T`` contiguous (T, D) slabs — O(n/T) scatter updates
   instead of O(n).

Correctness requires the contract the catalog layer guarantees by
construction: ``seg_ids`` is sorted ascending AND dense (every id in
``[min_id, max_id]`` occurs at least once). Then a tile of T sorted rows
spans at most T consecutive ids, so every row's id fits in the 2T-wide
window anchored at ``(seg_ids[tile_start] // T) * T`` (the run of any id
strictly inside the tile's id range lies entirely inside the tile).

Two reductions, mirroring the catalog's needs:

* ``segment_sum_sorted`` — MXU one-hot matmul accumulation (counts, centers
  of mass, mean velocities, Σ|v|²);
* ``segment_max_sorted`` — same tiling with a VPU masked-max epilogue
  (per-halo max radius).

Pure-jnp oracles with identical contracts live in ``kernels/ref.py``
(``segment_sum_sorted_ref`` / ``segment_max_sorted_ref``). Padding: row
padding appended by the wrappers reuses the last real segment id with
neutral data (0 for sum, ``-SEG_NEG_BIG`` for max), so it never perturbs
real segments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import INTERPRET

SEG_NEG_BIG = 1e30  # neutral element magnitude for the max reduction

__all__ = ["SEG_NEG_BIG", "segment_sum_sorted", "segment_max_sorted"]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _sum_kernel(base_ref, seg_ref, x_ref, o_ref):
    """One row tile -> one (2T, D) aligned partial via a one-hot matmul."""
    t = seg_ref.shape[0]
    base = base_ref[pl.program_id(0)]                      # T-aligned segment row
    local = seg_ref[...] - base                            # in [0, 2T) by contract
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, 2 * t), 1)
    onehot = (cols == local[:, None]).astype(jnp.float32)  # (T, 2T)
    o_ref[0] = jax.lax.dot_general(
        onehot, x_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (2T, D)


def _max_kernel(base_ref, seg_ref, x_ref, o_ref):
    t = seg_ref.shape[0]
    base = base_ref[pl.program_id(0)]
    local = seg_ref[...] - base
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, 2 * t), 1)
    hit = cols == local[:, None]                            # (T, 2T)
    cand = jnp.where(hit[:, :, None], x_ref[...][:, None, :], -SEG_NEG_BIG)
    o_ref[0] = jnp.max(cand, axis=0)                        # (2T, D)


def _prepare(data, seg_ids, num_segments, tile, pad_value):
    """Pad rows/features to tile multiples; compute per-tile aligned bases."""
    n, d = data.shape
    npad = _round_up(max(n, tile), tile)
    dp = _round_up(max(d, 1), 8)
    x = jnp.pad(data.astype(jnp.float32), ((0, npad - n), (0, dp - d)),
                constant_values=pad_value)
    seg = jnp.clip(seg_ids.astype(jnp.int32), 0, num_segments - 1)
    # Row padding reuses the LAST real id: stays sorted, window math holds.
    seg = jnp.pad(seg, (0, npad - n), mode="edge" if n > 0 else "constant")
    num_tiles = npad // tile
    heads = seg[jnp.arange(num_tiles, dtype=jnp.int32) * tile]
    blk = heads // tile                                     # aligned block index
    return x, seg, blk, num_tiles, dp


def _combine(partials, blk, num_segments, tile, d, dp, init, combine_at):
    """Scatter the T-aligned (2T, D) partials into the (S, D) output:
    n/T slab updates instead of n row updates."""
    num_blocks = num_segments // tile + 2  # blk+1 always in range
    out = jnp.full((num_blocks, tile, dp), init, jnp.float32)
    out = combine_at(out, blk, partials[:, :tile, :])
    out = combine_at(out, blk + 1, partials[:, tile:, :])
    return out.reshape(num_blocks * tile, dp)[:num_segments, :d]


@functools.partial(jax.jit, static_argnames=("num_segments", "tile", "interpret"))
def segment_sum_sorted(data: jax.Array, seg_ids: jax.Array, num_segments: int,
                       *, tile: int = 128,
                       interpret: bool = INTERPRET) -> jax.Array:
    """out[s, :] = Σ data[i, :] over i with seg_ids[i] == s.

    ``seg_ids`` must be sorted ascending and dense (see module docstring);
    rows the caller wants excluded must be zeroed, not re-labeled.
    """
    n, d = data.shape
    x, seg, blk, num_tiles, dp = _prepare(data, seg_ids, num_segments, tile, 0.0)
    partials = pl.pallas_call(
        _sum_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile, dp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2 * tile, dp), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tiles, 2 * tile, dp), jnp.float32),
        interpret=interpret,
    )(blk * tile, seg, x)
    return _combine(partials, blk, num_segments, tile, d, dp, 0.0,
                    lambda o, idx, upd: o.at[idx].add(upd))


@functools.partial(jax.jit, static_argnames=("num_segments", "tile", "interpret"))
def segment_max_sorted(data: jax.Array, seg_ids: jax.Array, num_segments: int,
                       *, tile: int = 128,
                       interpret: bool = INTERPRET) -> jax.Array:
    """out[s, :] = max data[i, :] over i with seg_ids[i] == s; empty segments
    come back at ``-SEG_NEG_BIG`` (callers mask on their own count).

    Same sorted+dense contract as ``segment_sum_sorted``; rows to exclude
    must be set to ``-SEG_NEG_BIG`` by the caller.
    """
    n, d = data.shape
    x, seg, blk, num_tiles, dp = _prepare(data, seg_ids, num_segments, tile,
                                          -SEG_NEG_BIG)
    partials = pl.pallas_call(
        _max_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile, dp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2 * tile, dp), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_tiles, 2 * tile, dp), jnp.float32),
        interpret=interpret,
    )(blk * tile, seg, x)
    return _combine(partials, blk, num_segments, tile, d, dp, -SEG_NEG_BIG,
                    lambda o, idx, upd: o.at[idx].max(upd))
