"""Pure-jnp oracles for every kernel in this package (no Pallas).

Each function mirrors the exact contract of its `pairwise.py` counterpart,
including padding semantics, so tests can sweep shapes/dtypes and
``assert_allclose`` kernel vs oracle.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.pairwise import SENTINEL_LABEL

__all__ = [
    "pairwise_count_ref",
    "pairwise_min_label_ref",
    "stencil_count_ref",
    "stencil_min_label_ref",
    "segment_sum_sorted_ref",
    "segment_max_sorted_ref",
]


def _dist2(x, y):
    return jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)


def pairwise_count_ref(x, y, eps2):
    return jnp.sum(_dist2(x, y) <= eps2, axis=1).astype(jnp.int32)


def pairwise_min_label_ref(x, y, labels, core, eps2):
    ok = (_dist2(x, y) <= eps2) & core[None, :]
    cand = jnp.where(ok, labels[None, :], SENTINEL_LABEL)
    return jnp.min(cand, axis=1).astype(jnp.int32)


def stencil_count_ref(cell_pts, nbr_map, eps2):
    ncells, s = nbr_map.shape
    counts = jnp.zeros(cell_pts.shape[:2], jnp.int32)[: ncells]
    for j in range(s):
        cand = cell_pts[nbr_map[:, j]]                     # (ncells, C, D)
        d2 = jnp.sum((cell_pts[:ncells, :, None, :] - cand[:, None, :, :]) ** 2, -1)
        counts = counts + jnp.sum(d2 <= eps2, axis=2).astype(jnp.int32)
    return counts


def segment_sum_sorted_ref(data, seg_ids, num_segments):
    """Oracle for ``segment.segment_sum_sorted`` (works for unsorted ids too;
    the kernel additionally requires sorted+dense — see its docstring)."""
    seg = jnp.clip(seg_ids.astype(jnp.int32), 0, num_segments - 1)
    return jnp.zeros((num_segments, data.shape[1]), jnp.float32) \
        .at[seg].add(data.astype(jnp.float32))


def segment_max_sorted_ref(data, seg_ids, num_segments):
    """Oracle for ``segment.segment_max_sorted``; empty segments come back at
    ``-segment.SEG_NEG_BIG`` just like the kernel."""
    from repro.kernels.segment import SEG_NEG_BIG
    seg = jnp.clip(seg_ids.astype(jnp.int32), 0, num_segments - 1)
    return jnp.full((num_segments, data.shape[1]), -SEG_NEG_BIG, jnp.float32) \
        .at[seg].max(data.astype(jnp.float32))


def stencil_min_label_ref(cell_pts, cell_labels, cell_core, nbr_map, eps2):
    ncells, s = nbr_map.shape
    out = jnp.full(cell_pts.shape[:2], SENTINEL_LABEL, jnp.int32)[: ncells]
    for j in range(s):
        nb = nbr_map[:, j]
        cand = cell_pts[nb]
        d2 = jnp.sum((cell_pts[:ncells, :, None, :] - cand[:, None, :, :]) ** 2, -1)
        ok = (d2 <= eps2) & cell_core[nb][:, None, :]
        lab = jnp.where(ok, cell_labels[nb][:, None, :], SENTINEL_LABEL)
        out = jnp.minimum(out, jnp.min(lab, axis=2))
    return out
