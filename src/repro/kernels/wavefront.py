"""Pallas wavefront traversal: one batched BVH kernel under every query.

The engine's ``backend="pallas"``.  A grid step owns a *block* of
(ideally Morton-sorted) queries; the BVH node arrays (``rope`` /
``left_child`` / ``node_lo`` / ``node_hi`` / ``leaf_perm``) are staged
into the kernel as full-array blocks (VMEM on TPU), and each inner
``while_loop`` iteration advances every query in the block one rope hop
— the warp-style wavefront the source paper credits for its largest
wins (§4.1.1, §4.3.3), with the callback fused as the epilogue of the
leaf test exactly as in the vmapped cores.

Two entry points mirror the two traversal shapes the engine stages:

* :func:`wavefront_traverse` — the count/callback pass behind
  ``query``/``query_count`` (optionally carrying the ``TraversalStats``
  counters in the loop state when ``with_stats=True``);
* :func:`wavefront_fill_round` — one resumable chunk round of the
  ``query_csr_device`` scatter-fill protocol (per-lane node cursor in,
  ``(block, chunk)`` hit buffer out), driven by the engine's outer
  emit loop.

Closure discipline: a Pallas kernel body must not capture outer traced
arrays, so callers pass a ``make_fns(tree)`` *factory* instead of
prebuilt ``node_fn``/``leaf_fn`` closures.  The factory is re-invoked
inside the kernel on a :class:`TreeView` built from kernel-local ref
reads, giving closures whose captured arrays live in kernel memory.
On CPU the kernel runs in interpret mode (same numerics, used by CI);
on TPU it compiles natively.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bvh import SENTINEL

from repro.kernels.ops import INTERPRET, pad_rows, pad_rows_edge, round_up

__all__ = ["BLOCK_Q", "TreeView", "wavefront_traverse", "wavefront_fill_round"]

# Default queries per grid step. 128 matches the TPU lane width; interpret
# mode accepts anything.
BLOCK_Q = 128

# Python-int twin of core.bvh.SENTINEL for use INSIDE kernel bodies: a
# Pallas kernel may not capture jnp array constants (SENTINEL is a
# jnp.int32 scalar).
_SENT = int(SENTINEL)


class TreeView(NamedTuple):
    """Kernel-local view of the BVH arrays a rope traversal needs.

    Duck-types the subset of ``Bvh`` that ``core.query``'s predicate
    factories read (``node_lo``/``node_hi``/``leaf_perm``/``num_leaves``),
    so the same ``_pred_fns`` code builds closures against either the
    host-side tree or this in-kernel view.
    """

    leaf_perm: jax.Array
    left_child: jax.Array
    rope: jax.Array
    node_lo: jax.Array
    node_hi: jax.Array

    @property
    def num_leaves(self) -> int:
        return self.leaf_perm.shape[0]


def _tree_arrays(bvh) -> tuple:
    return (bvh.leaf_perm, bvh.left_child, bvh.rope, bvh.node_lo, bvh.node_hi)


def _full_spec(a: jax.Array) -> pl.BlockSpec:
    nd = a.ndim
    return pl.BlockSpec(a.shape, lambda i, _nd=nd: (0,) * _nd)


def _lane_spec(a: jax.Array, bq: int) -> pl.BlockSpec:
    nd = a.ndim
    return pl.BlockSpec((bq,) + a.shape[1:], lambda i, _nd=nd: (i,) + (0,) * (_nd - 1))


def _block_size(q: int, block_q: int) -> tuple[int, int]:
    bq = min(int(block_q), max(8, round_up(q, 8)))
    return bq, round_up(q, bq)


def _bcast(mask: jax.Array, ndim: int) -> jax.Array:
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def wavefront_traverse(bvh, qdata, make_fns: Callable, carry_init, *,
                       start_nodes: jax.Array | None = None,
                       with_stats: bool = False,
                       depths: jax.Array | None = None,
                       block_q: int = BLOCK_Q,
                       interpret: bool = INTERPRET):
    """Run the rope traversal for every query as a blocked wavefront.

    ``qdata`` is the engine's per-query pytree (leading dim = queries);
    ``make_fns(tree)`` must return ``(node_fn, leaf_fn)`` with the engine
    contracts (``node_fn(q, carry, node) -> bool``,
    ``leaf_fn(q, carry, obj, sorted_idx) -> (carry, done)``) built against
    the :class:`TreeView` it receives.  ``carry_init`` is broadcast to one
    carry per query.  ``start_nodes`` defaults to the root for every lane;
    padded lanes start at ``SENTINEL`` and never move.

    Returns the per-query carries, or with ``with_stats=True`` (which
    requires the node ``depths`` table) the tuple
    ``(carries, (nodes, aabb, leaf, maxd, done))`` matching the engine's
    ``_stats_from_raw`` layout.
    """
    leaves = jax.tree.leaves(qdata)
    if not leaves:
        raise ValueError("qdata must contain at least one per-query array")
    q = leaves[0].shape[0]
    if with_stats and depths is None:
        raise ValueError("with_stats=True requires the node depth table")
    if q == 0:
        carries = jax.tree.map(
            lambda x: jnp.broadcast_to(jnp.asarray(x), (0,) + jnp.shape(x)),
            carry_init)
        if not with_stats:
            return carries
        z = jnp.zeros((0,), jnp.int32)
        return carries, (z, z, z, z, jnp.zeros((0,), bool))

    bq, qp = _block_size(q, block_q)
    qdata_p = jax.tree.map(lambda x: pad_rows_edge(x, qp), qdata)
    if start_nodes is None:
        start = jnp.zeros((q,), jnp.int32)
    else:
        start = start_nodes.astype(jnp.int32)
    start = pad_rows(start, qp, SENTINEL)
    carries_p = jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.asarray(x), (qp,) + jnp.shape(x)),
        carry_init)

    q_flat, q_def = jax.tree.flatten(qdata_p)
    c_flat, c_def = jax.tree.flatten(carries_p)
    n_q, n_c = len(q_flat), len(c_flat)

    tree_arrs = _tree_arrays(bvh)
    inputs: list = list(tree_arrs)
    in_specs = [_full_spec(a) for a in tree_arrs]
    if with_stats:
        inputs.append(depths)
        in_specs.append(_full_spec(depths))
    inputs.append(start)
    in_specs.append(_lane_spec(start, bq))
    inputs += q_flat
    in_specs += [_lane_spec(a, bq) for a in q_flat]
    inputs += c_flat
    in_specs += [_lane_spec(a, bq) for a in c_flat]

    out_shape = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in c_flat]
    out_specs = [_lane_spec(a, bq) for a in c_flat]
    if with_stats:
        for dt in (jnp.int32, jnp.int32, jnp.int32, jnp.int32, jnp.bool_):
            out_shape.append(jax.ShapeDtypeStruct((qp,), dt))
            out_specs.append(pl.BlockSpec((bq,), lambda i: (i,)))

    def kernel(*refs):
        it = iter(refs)
        tree = TreeView(*(next(it)[...] for _ in range(5)))
        depth_tab = next(it)[...] if with_stats else None
        node0 = next(it)[...]
        qblock = jax.tree.unflatten(q_def, [next(it)[...] for _ in range(n_q)])
        carry0 = jax.tree.unflatten(c_def, [next(it)[...] for _ in range(n_c)])
        out_refs = list(it)

        node_fn, leaf_fn = make_fns(tree)
        n = tree.num_leaves

        def cond(state):
            node, done = state[0], state[2]
            return jnp.any((node != _SENT) & ~done)

        def body(state):
            node, carry, done, nodes, aabb, leafs, maxd = state
            live = (node != _SENT) & ~done
            # Dead lanes sit at SENTINEL; clip every gather index so they
            # read node 0 harmlessly and are masked out below.
            node_s = jnp.clip(node, 0, 2 * n - 2)
            leaf_raw = node_s >= n - 1
            is_leaf = live & leaf_raw
            sorted_idx = node_s - (n - 1)
            objs = tree.leaf_perm[jnp.clip(sorted_idx, 0, n - 1)]

            carry_leaf, done_leaf = jax.vmap(leaf_fn)(
                qblock, carry, objs, sorted_idx)
            hit = jax.vmap(node_fn)(qblock, carry, node_s)
            node_c = jnp.clip(node_s, 0, n - 2)
            nxt = jnp.where(
                leaf_raw, tree.rope[node_s],
                jnp.where(hit, tree.left_child[node_c], tree.rope[node_s]))

            if with_stats:
                nodes = nodes + live.astype(jnp.int32)
                aabb = aabb + (live & ~leaf_raw).astype(jnp.int32)
                leafs = leafs + is_leaf.astype(jnp.int32)
                maxd = jnp.where(
                    live, jnp.maximum(maxd, depth_tab[node_s]), maxd)

            carry = jax.tree.map(
                lambda a, b: jnp.where(_bcast(is_leaf, a.ndim), a, b),
                carry_leaf, carry)
            done = done | (is_leaf & done_leaf)
            node = jnp.where(live, nxt, node)
            return node, carry, done, nodes, aabb, leafs, maxd

        z = jnp.zeros(node0.shape, jnp.int32)
        state0 = (node0, carry0, jnp.zeros(node0.shape, bool), z, z, z, z)
        _, carry, done, nodes, aabb, leafs, maxd = jax.lax.while_loop(
            cond, body, state0)

        outs = list(jax.tree.leaves(carry))
        if with_stats:
            outs += [nodes, aabb, leafs, maxd, done]
        for ref, val in zip(out_refs, outs):
            ref[...] = val

    outs = pl.pallas_call(
        kernel,
        grid=(qp // bq,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    if not isinstance(outs, (list, tuple)):
        outs = [outs]
    carry_out = jax.tree.unflatten(c_def, [o[:q] for o in outs[:n_c]])
    if not with_stats:
        return carry_out
    nodes, aabb, leafs, maxd, done = (o[:q] for o in outs[n_c:])
    return carry_out, (nodes, aabb, leafs, maxd, done)


def wavefront_fill_round(bvh, qdata, make_fns: Callable,
                         node_state: jax.Array, chunk: int, *,
                         block_q: int = BLOCK_Q,
                         interpret: bool = INTERPRET):
    """One chunk round of the resumable CSR scatter-fill, as a wavefront.

    ``make_fns(tree)`` must return ``(node_fn, leaf_aux)`` where
    ``leaf_aux(q, sorted_idx) -> (d2, hit)`` is the engine's predicate
    leaf test.  Each lane resumes from its ``node_state`` cursor, records
    up to ``chunk`` hit object ids into its buffer row, and parks either
    at ``SENTINEL`` (traversal finished) or at the node that would
    overflow the chunk (the engine's outer loop scatters the buffers and
    re-enters).  Mirrors the vmapped scalar ``round_one`` hop-for-hop.

    Returns ``(node_state, bufs, counts)`` with shapes
    ``(q,), (q, chunk), (q,)``.
    """
    q = node_state.shape[0]
    chunk = max(int(chunk), 1)
    if q == 0:
        return (node_state,
                jnp.full((0, chunk), -1, jnp.int32),
                jnp.zeros((0,), jnp.int32))

    bq, qp = _block_size(q, block_q)
    qdata_p = jax.tree.map(lambda x: pad_rows_edge(x, qp), qdata)
    state_p = pad_rows(node_state.astype(jnp.int32), qp, SENTINEL)
    q_flat, q_def = jax.tree.flatten(qdata_p)
    n_q = len(q_flat)

    tree_arrs = _tree_arrays(bvh)
    inputs = list(tree_arrs) + [state_p] + q_flat
    in_specs = ([_full_spec(a) for a in tree_arrs]
                + [_lane_spec(state_p, bq)]
                + [_lane_spec(a, bq) for a in q_flat])
    out_shape = [
        jax.ShapeDtypeStruct((qp,), jnp.int32),
        jax.ShapeDtypeStruct((qp, chunk), jnp.int32),
        jax.ShapeDtypeStruct((qp,), jnp.int32),
    ]
    out_specs = [
        pl.BlockSpec((bq,), lambda i: (i,)),
        pl.BlockSpec((bq, chunk), lambda i: (i, 0)),
        pl.BlockSpec((bq,), lambda i: (i,)),
    ]

    def kernel(*refs):
        it = iter(refs)
        tree = TreeView(*(next(it)[...] for _ in range(5)))
        node0 = next(it)[...]
        qblock = jax.tree.unflatten(q_def, [next(it)[...] for _ in range(n_q)])
        node_out, buf_out, nh_out = it

        node_fn, leaf_aux = make_fns(tree)
        n = tree.num_leaves

        def cond(state):
            node, _, nh = state
            return jnp.any((node != _SENT) & (nh < chunk))

        def body(state):
            node, buf, nh = state
            active = (node != _SENT) & (nh < chunk)
            node_s = jnp.clip(node, 0, 2 * n - 2)
            leaf_raw = node_s >= n - 1
            sorted_idx = jnp.clip(node_s - (n - 1), 0, n - 1)
            _, hit = jax.vmap(leaf_aux)(qblock, sorted_idx)
            take = active & leaf_raw & hit
            objs = tree.leaf_perm[sorted_idx]
            # One-hot write into each lane's next free slot.
            lane = jax.lax.broadcasted_iota(jnp.int32, (node.shape[0], chunk), 1)
            slot = jnp.clip(nh, 0, chunk - 1)
            write = take[:, None] & (lane == slot[:, None])
            buf = jnp.where(write, objs[:, None], buf)
            nh = nh + take.astype(jnp.int32)
            descend = jax.vmap(lambda qq, nd: node_fn(qq, None, nd))(
                qblock, node_s)
            node_c = jnp.clip(node_s, 0, n - 2)
            nxt = jnp.where(
                leaf_raw, tree.rope[node_s],
                jnp.where(descend, tree.left_child[node_c], tree.rope[node_s]))
            node = jnp.where(active, nxt, node)
            return node, buf, nh

        buf0 = jnp.full((node0.shape[0], chunk), -1, jnp.int32)
        nh0 = jnp.zeros(node0.shape, jnp.int32)
        node, buf, nh = jax.lax.while_loop(cond, body, (node0, buf0, nh0))
        node_out[...] = node
        buf_out[...] = buf
        nh_out[...] = nh

    node, buf, nh = pl.pallas_call(
        kernel,
        grid=(qp // bq,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    return node[:q], buf[:q], nh[:q]
