"""Public jit'd wrappers around the Pallas kernels: padding, tiling, unpadding.

On a real TPU the kernels compile natively (``interpret=False``); on CPU they
run the kernel body in interpret mode — same numerics, used by every test.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import pairwise as _k

INTERPRET = jax.default_backend() != "tpu"

__all__ = [
    "eps_neighbor_counts",
    "eps_min_label",
    "cell_stencil_counts",
    "cell_stencil_min_label",
    "round_up",
    "pad_rows",
    "pad_rows_edge",
]


def round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def pad_rows(a: jax.Array, rows: int, fill) -> jax.Array:
    """Pad the leading dim of ``a`` to ``rows`` with ``fill``."""
    pad = rows - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1), constant_values=fill)


def pad_rows_edge(a: jax.Array, rows: int) -> jax.Array:
    """Pad the leading dim of ``a`` to ``rows`` by replicating the last row.

    Used by the wavefront kernel for per-query payloads: replicated rows carry
    valid geometry so the kernel math never sees NaN/garbage, while the lane
    itself is killed by a SENTINEL start node.
    """
    pad = rows - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1), mode="edge")


# Backward-compatible private aliases (pre-wavefront internal names).
_round_up = round_up
_pad_rows = pad_rows


def _pad_dim(a: jax.Array, d: int) -> jax.Array:
    pad = d - a.shape[1]
    if pad == 0:
        return a
    # Zero-pad feature dim: contributes 0 to distances for real rows; padded
    # rows already live at BIG in the padded dims that exist.
    return jnp.pad(a, [(0, 0), (0, pad)])


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def eps_neighbor_counts(x: jax.Array, y: jax.Array, eps,
                        *, tm: int = 128, tn: int = 128,
                        interpret: bool = INTERPRET) -> jax.Array:
    """|N_ε(x_i)| against point set y. Arbitrary (m, d), (n, d) float32."""
    m, d = x.shape
    n = y.shape[0]
    dp = _round_up(max(d, 1), 8)
    xp = _pad_dim(_pad_rows(x.astype(jnp.float32), _round_up(m, tm), _k.BIG), dp)
    yp = _pad_dim(_pad_rows(y.astype(jnp.float32), _round_up(n, tn), _k.BIG), dp)
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    out = _k.pairwise_count(xp, yp, eps2, tm=tm, tn=tn, interpret=interpret)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def eps_min_label(x: jax.Array, y: jax.Array, labels: jax.Array, core: jax.Array,
                  eps, *, tm: int = 128, tn: int = 128,
                  interpret: bool = INTERPRET) -> jax.Array:
    """min label over ε-reachable core y-points; SENTINEL_LABEL when none."""
    m, d = x.shape
    n = y.shape[0]
    dp = _round_up(max(d, 1), 8)
    xp = _pad_dim(_pad_rows(x.astype(jnp.float32), _round_up(m, tm), _k.BIG), dp)
    yp = _pad_dim(_pad_rows(y.astype(jnp.float32), _round_up(n, tn), _k.BIG), dp)
    lp = _pad_rows(labels.astype(jnp.int32), _round_up(n, tn), _k.SENTINEL_LABEL)
    cp = _pad_rows(core.astype(bool), _round_up(n, tn), False)
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    out = _k.pairwise_min_label(xp, yp, lp, cp, eps2, tm=tm, tn=tn, interpret=interpret)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cell_stencil_counts(cell_pts: jax.Array, nbr_map: jax.Array, eps,
                        *, interpret: bool = INTERPRET) -> jax.Array:
    """(ncells+1, C, D) slot-padded cells -> (ncells, C) ε-counts."""
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    return _k.stencil_count(cell_pts, nbr_map, eps2, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cell_stencil_min_label(cell_pts: jax.Array, cell_labels: jax.Array,
                           cell_core: jax.Array, nbr_map: jax.Array, eps,
                           *, interpret: bool = INTERPRET) -> jax.Array:
    eps2 = jnp.asarray(eps, jnp.float32) ** 2
    return _k.stencil_min_label(cell_pts, cell_labels, cell_core, nbr_map, eps2,
                                interpret=interpret)
