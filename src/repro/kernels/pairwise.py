"""Pallas TPU kernels for ε-neighborhood primitives (DESIGN.md §2, TPU tier).

The paper's hot loop — BVH traversal with a fused callback (§4.1.1, §4.3.3) —
is a SIMT pointer-chase with no TPU analogue. The TPU-native reformulation
computes the same quantities as *tiled dense linear algebra* on the MXU:

    ‖xᵢ − yⱼ‖² = ‖xᵢ‖² + ‖yⱼ‖² − 2 xᵢ·yⱼ

with the −2xy term as a (TM, D) × (D, TN) matmul. The paper's callback is the
kernel *epilogue*, fused in VMEM (never materializing the (M, N) distance or
adjacency matrix — the O(n) memory property of FDBSCAN carries over):

* ``count`` epilogue   — |N_ε(x)| counting (core-point test, §4.1.2)
* ``minlabel`` epilogue — min cluster label over ε-reachable core neighbors
  (the UNION hook candidate, §4.2.3/§4.3.3)

Two kernel families:

* ``pairwise_*`` — all-pairs over row blocks of two point sets; grid
  (M/TM, N/TN) with accumulation over the N axis. Used for embedding-space
  clustering (in-situ analysis of d=64..4096 vectors) where the MXU
  contraction dimension is large.
* ``stencil_*`` — cosmology-style low-d points binned into ε-cells of fixed
  capacity C; grid (ncells, 3^d) where the candidate cell index comes from a
  scalar-prefetched neighbor map (SMEM), the TPU analogue of ArborX's
  cell-adjacency pruning (§4.3.4). Each (cell, stencil-slot) step is a
  (C, D) × (D, C) tile matmul.

Padding convention: padded points sit at ``BIG`` (1e15) so every distance to
them is ~1e30 ≫ ε²; padded labels are ``SENTINEL_LABEL`` (int32 max) and
padded core flags are False. All shapes are multiples of the block shapes —
``ops.py`` owns the padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 1e15  # padding coordinate; BIG**2 is finite in f32, so no NaNs
SENTINEL_LABEL = jnp.iinfo(jnp.int32).max

__all__ = [
    "BIG",
    "SENTINEL_LABEL",
    "pairwise_count",
    "pairwise_min_label",
    "stencil_count",
    "stencil_min_label",
]


def _dist2_tile(x, y):
    """(TM, D), (TN, D) -> (TM, TN) squared distances via the MXU."""
    xx = jnp.sum(x * x, axis=-1, keepdims=True)            # (TM, 1)
    yy = jnp.sum(y * y, axis=-1)[None, :]                  # (1, TN)
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    return xx + yy - 2.0 * xy


# ---------------------------------------------------------------------------
# All-pairs kernels: grid (M/TM, N/TN), accumulate over axis 1
# ---------------------------------------------------------------------------

def _count_kernel(x_ref, y_ref, eps2_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d2 = _dist2_tile(x_ref[...], y_ref[...])
    hits = (d2 <= eps2_ref[0]).astype(jnp.int32)
    o_ref[...] += jnp.sum(hits, axis=1)


def _minlabel_kernel(x_ref, y_ref, lab_ref, core_ref, eps2_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, SENTINEL_LABEL)

    d2 = _dist2_tile(x_ref[...], y_ref[...])
    ok = (d2 <= eps2_ref[0]) & (core_ref[...] != 0)[None, :]
    cand = jnp.where(ok, lab_ref[...][None, :], SENTINEL_LABEL)
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(cand, axis=1))


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def pairwise_count(x: jax.Array, y: jax.Array, eps2: jax.Array,
                   *, tm: int = 128, tn: int = 128,
                   interpret: bool = True) -> jax.Array:
    """counts[i] = |{j : ‖x_i − y_j‖² ≤ eps2}|. Shapes pre-padded to tiles."""
    m, d = x.shape
    n, _ = y.shape
    assert m % tm == 0 and n % tn == 0, (m, n, tm, tn)
    return pl.pallas_call(
        _count_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret,
    )(x, y, eps2.reshape(1))


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def pairwise_min_label(x: jax.Array, y: jax.Array, labels: jax.Array,
                       core: jax.Array, eps2: jax.Array,
                       *, tm: int = 128, tn: int = 128,
                       interpret: bool = True) -> jax.Array:
    """minlab[i] = min over ε-hits j with core[j] of labels[j] (else sentinel)."""
    m, d = x.shape
    n, _ = y.shape
    assert m % tm == 0 and n % tn == 0, (m, n, tm, tn)
    return pl.pallas_call(
        _minlabel_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret,
    )(x, y, labels, core.astype(jnp.int32), eps2.reshape(1))


# ---------------------------------------------------------------------------
# Stencil kernels: grid (ncells, n_stencil); candidate cell via scalar prefetch
# ---------------------------------------------------------------------------

def _stencil_count_kernel(nbr_ref, q_ref, c_ref, eps2_ref, o_ref):
    del nbr_ref  # consumed by the index maps
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0]          # (C, D)
    c = c_ref[0]          # (C, D)
    d2 = _dist2_tile(q, c)
    o_ref[0] += jnp.sum((d2 <= eps2_ref[0]).astype(jnp.int32), axis=1)


def _stencil_minlabel_kernel(nbr_ref, q_ref, c_ref, lab_ref, core_ref, eps2_ref, o_ref):
    del nbr_ref
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, SENTINEL_LABEL)

    d2 = _dist2_tile(q_ref[0], c_ref[0])
    ok = (d2 <= eps2_ref[0]) & (core_ref[0] != 0)[None, :]
    cand = jnp.where(ok, lab_ref[0][None, :], SENTINEL_LABEL)
    o_ref[0] = jnp.minimum(o_ref[0], jnp.min(cand, axis=1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def stencil_count(cell_pts: jax.Array, nbr_map: jax.Array, eps2: jax.Array,
                  *, interpret: bool = True) -> jax.Array:
    """Per-slot ε-neighbor counts over the cell stencil.

    cell_pts: (ncells+1, C, D) — slot-padded cells; the LAST cell is all
              padding and is the target of out-of-bounds stencil entries.
    nbr_map:  (ncells, S) int32 — candidate cell id per (cell, stencil slot).
    Returns (ncells, C) int32 counts (garbage at padded slots).
    """
    ncells_p1, cap, d = cell_pts.shape
    ncells, s = nbr_map.shape
    assert ncells_p1 == ncells + 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ncells, s),
        in_specs=[
            pl.BlockSpec((1, cap, d), lambda i, j, nbr: (i, 0, 0)),
            pl.BlockSpec((1, cap, d), lambda i, j, nbr: (nbr[i, j], 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, cap), lambda i, j, nbr: (i, 0)),
    )
    return pl.pallas_call(
        _stencil_count_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ncells, cap), jnp.int32),
        interpret=interpret,
    )(nbr_map, cell_pts, cell_pts, eps2.reshape(1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def stencil_min_label(cell_pts: jax.Array, cell_labels: jax.Array,
                      cell_core: jax.Array, nbr_map: jax.Array, eps2: jax.Array,
                      *, interpret: bool = True) -> jax.Array:
    """Per-slot min label over ε-reachable core points in the stencil.

    cell_labels: (ncells+1, C) int32 (sentinel at padding),
    cell_core:   (ncells+1, C) bool.
    Returns (ncells, C) int32.
    """
    ncells_p1, cap, d = cell_pts.shape
    ncells, s = nbr_map.shape
    assert ncells_p1 == ncells + 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ncells, s),
        in_specs=[
            pl.BlockSpec((1, cap, d), lambda i, j, nbr: (i, 0, 0)),
            pl.BlockSpec((1, cap, d), lambda i, j, nbr: (nbr[i, j], 0, 0)),
            pl.BlockSpec((1, cap), lambda i, j, nbr: (nbr[i, j], 0)),
            pl.BlockSpec((1, cap), lambda i, j, nbr: (nbr[i, j], 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, cap), lambda i, j, nbr: (i, 0)),
    )
    return pl.pallas_call(
        _stencil_minlabel_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ncells, cap), jnp.int32),
        interpret=interpret,
    )(nbr_map, cell_pts, cell_pts, cell_labels, cell_core.astype(jnp.int32),
      eps2.reshape(1))
