"""Unified query engine (core/query.py): backend equivalence on adversarial
inputs, CSR output protocols vs numpy oracles, overflow-retry, predicate
surface, and engine-level Morton query sorting."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bvh import build_bvh
from repro.core.query import (
    intersects_box,
    nearest,
    query,
    query_count,
    query_csr,
    query_csr_buffered,
    query_fixed,
    within,
)


def _bvh(pts):
    lo = pts.min(0) - 1e-4
    hi = pts.max(0) + 1e-4
    return build_bvh(jnp.asarray(pts), jnp.asarray(lo), jnp.asarray(hi))


def _d2(pts, queries):
    return ((queries[:, None] - pts[None]) ** 2).sum(-1, dtype=np.float32)


# --- adversarial datasets (degenerate Morton codes, ties, minimal n) --------

def _adversarial(name):
    rng = np.random.default_rng(42)
    if name == "duplicates":
        return np.broadcast_to(np.float32([0.3, 0.7, 0.5]), (16, 3)).copy()
    if name == "collinear":
        t = np.linspace(0, 1, 33, dtype=np.float32)
        return np.stack([t, 2 * t, -t], 1)
    if name == "n2":
        return rng.uniform(0, 1, (2, 3)).astype(np.float32)
    if name == "random":
        return rng.uniform(0, 1, (64, 3)).astype(np.float32)
    raise KeyError(name)


ADVERSARIAL = ["duplicates", "collinear", "n2", "random"]


@pytest.mark.parametrize("dataset", ADVERSARIAL)
@pytest.mark.parametrize("eps", [0.0, 0.25])
def test_counts_backends_match_bruteforce(dataset, eps):
    """stackless == stack == pallas == numpy brute force, including eps=0
    (only exact coincidences count) and all-duplicate / collinear / n=2
    point sets."""
    pts = _adversarial(dataset)
    bvh = _bvh(pts)
    want = (_d2(pts, pts) <= np.float32(eps) ** 2).sum(1)
    for backend in ("stackless", "stack", "pallas"):
        got = np.asarray(query_count(bvh, within(jnp.asarray(pts), eps),
                                     backend=backend))
        np.testing.assert_array_equal(got, want, err_msg=backend)


@pytest.mark.parametrize("dataset", ADVERSARIAL)
def test_csr_backends_match_bruteforce(dataset):
    """CSR neighbor lists agree across backends and with the numpy oracle
    (as sets per row — traversal order differs by design)."""
    pts = _adversarial(dataset)
    bvh = _bvh(pts)
    eps = 0.3
    adj = _d2(pts, pts) <= np.float32(eps) ** 2
    per_backend = {}
    for backend in ("stackless", "stack", "pallas"):
        res = query_csr(bvh, within(jnp.asarray(pts), eps), backend=backend)
        offs, idx = np.asarray(res.offsets), np.asarray(res.indices)
        assert not bool(res.overflowed)
        assert int(res.total) == int(adj.sum())
        np.testing.assert_array_equal(np.diff(offs), adj.sum(1))
        rows = [frozenset(idx[offs[i]:offs[i + 1]].tolist())
                for i in range(len(pts))]
        for i, row in enumerate(rows):
            assert row == frozenset(np.nonzero(adj[i])[0].tolist()), (backend, i)
        per_backend[backend] = rows
    assert per_backend["stackless"] == per_backend["stack"]
    assert per_backend["stackless"] == per_backend["pallas"]


@pytest.mark.parametrize("dataset", ADVERSARIAL)
def test_knn_matches_bruteforce_adversarial(dataset):
    pts = _adversarial(dataset)
    k = min(3, len(pts))
    bvh = _bvh(pts)
    res = query(bvh, nearest(jnp.asarray(pts), k))
    want = np.sort(np.sqrt(_d2(pts, pts)), axis=1)[:, :k]
    np.testing.assert_allclose(np.asarray(res.distances), want, atol=1e-5)


@given(n=st.integers(2, 60), eps=st.floats(0.0, 0.5), seed=st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_count_property_backends_agree(n, eps, seed):
    pts = np.random.default_rng(seed).uniform(0, 1, (n, 3)).astype(np.float32)
    bvh = _bvh(pts)
    want = (_d2(pts, pts) <= np.float32(eps) ** 2).sum(1)
    for backend in ("stackless", "stack", "pallas"):
        got = np.asarray(query_count(bvh, within(jnp.asarray(pts), eps),
                                     backend=backend))
        np.testing.assert_array_equal(got, want, err_msg=backend)


# --- output protocols --------------------------------------------------------

@pytest.mark.parametrize("backend", ["stackless", "pallas"])
def test_buffered_csr_overflow_retry(backend):
    """Force an undersized first buffer: capacity=1 on a clustered set whose
    neighborhoods hold dozens of points — the single-pass protocol must
    detect overflow, double, and converge to the two-pass result."""
    rng = np.random.default_rng(11)
    pts = (rng.uniform(0, 0.05, (80, 3)) +
           np.float32([0.5, 0.5, 0.5])).astype(np.float32)  # one dense blob
    bvh = _bvh(pts)
    pred = within(jnp.asarray(pts), 0.2)

    _, counts, overflowed = query_fixed(bvh, pred, capacity=1, backend=backend)
    assert bool(overflowed) and int(jnp.max(counts)) > 1  # the trap is armed

    buf = query_csr_buffered(bvh, pred, capacity=1, backend=backend)
    two = query_csr(bvh, pred)
    np.testing.assert_array_equal(np.asarray(buf.offsets),
                                  np.asarray(two.offsets))
    np.testing.assert_array_equal(np.asarray(buf.indices),
                                  np.asarray(two.indices))
    # the retry count is observable: capacity=1 must have re-run at least once
    assert buf.attempts > 1 and buf.overflowed


def test_query_fixed_reports_true_counts():
    pts = _adversarial("duplicates")
    bvh = _bvh(pts)
    buf, counts, overflowed = query_fixed(bvh, within(jnp.asarray(pts), 0.1),
                                          capacity=4)
    assert bool(overflowed)
    np.testing.assert_array_equal(np.asarray(counts), 16)  # true, not clamped
    assert buf.shape == (16, 4)


def test_count_early_termination_saturates():
    pts = _adversarial("duplicates")
    bvh = _bvh(pts)
    got = np.asarray(query_count(bvh, within(jnp.asarray(pts), 0.1), stop_at=5))
    np.testing.assert_array_equal(got, 5)


# --- predicate surface -------------------------------------------------------

def test_intersects_box_matches_bruteforce():
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 1, (100, 3)).astype(np.float32)
    qlo = rng.uniform(0, 0.8, (20, 3)).astype(np.float32)
    qhi = qlo + rng.uniform(0.05, 0.3, (20, 3)).astype(np.float32)
    bvh = _bvh(pts)
    got = np.asarray(query_count(
        bvh, intersects_box(jnp.asarray(qlo), jnp.asarray(qhi))))
    want = ((pts[None] >= qlo[:, None]) & (pts[None] <= qhi[:, None])) \
        .all(-1).sum(1)
    np.testing.assert_array_equal(got, want)


def test_per_query_radii():
    """within() with a per-query radius vector (the SO-mass use case)."""
    rng = np.random.default_rng(5)
    pts = rng.uniform(0, 1, (80, 3)).astype(np.float32)
    radii = rng.uniform(0.0, 0.4, (80,)).astype(np.float32)
    bvh = _bvh(pts)
    got = np.asarray(query_count(bvh, within(jnp.asarray(pts),
                                             jnp.asarray(radii))))
    want = (_d2(pts, pts) <= radii[:, None] ** 2).sum(1)
    np.testing.assert_array_equal(got, want)


def test_pair_backend_counts_each_pair_once():
    rng = np.random.default_rng(9)
    pts = rng.uniform(0, 1, (50, 3)).astype(np.float32)
    bvh = _bvh(pts)
    eps = 0.35

    def cb(c, i, j, d2):
        return c + 1, jnp.bool_(False)

    per_q = np.asarray(query(bvh, within(jnp.asarray(pts), eps), cb,
                             jnp.int32(0), backend="pair"))
    adj = _d2(pts, pts) <= np.float32(eps) ** 2
    assert per_q.sum() == (adj.sum() - len(pts)) // 2


def test_callback_early_exit():
    """§4.1.2: traversal stops once the callback reports done."""
    pts = _adversarial("duplicates")
    bvh = _bvh(pts)
    cap = 3

    def cb(c, qi, j, d2):
        c = c + 1
        return c, c >= cap

    got = np.asarray(query(bvh, within(jnp.asarray(pts), 1.0), cb, jnp.int32(0)))
    np.testing.assert_array_equal(got, cap)


# --- engine-level Morton query sorting (§4.2.2) ------------------------------

@pytest.mark.parametrize("protocol", ["count", "csr", "nearest"])
def test_sort_queries_is_transparent(protocol):
    """sort_queries permutes traversal order only: outputs are positionally
    identical to the unsorted run for every protocol."""
    rng = np.random.default_rng(17)
    pts = rng.uniform(0, 1, (90, 3)).astype(np.float32)
    queries = rng.uniform(-0.2, 1.2, (40, 3)).astype(np.float32)  # some outside
    bvh = _bvh(pts)
    if protocol == "count":
        a = query_count(bvh, within(jnp.asarray(queries), 0.3))
        b = query_count(bvh, within(jnp.asarray(queries), 0.3), sort_queries=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    elif protocol == "csr":
        res_a = query_csr(bvh, within(jnp.asarray(queries), 0.3))
        res_b = query_csr(bvh, within(jnp.asarray(queries), 0.3),
                          sort_queries=True)
        np.testing.assert_array_equal(np.asarray(res_a.offsets),
                                      np.asarray(res_b.offsets))
        offs_a = np.asarray(res_a.offsets)
        idx_a, idx_b = np.asarray(res_a.indices), np.asarray(res_b.indices)
        for i in range(len(queries)):
            assert (set(idx_a[offs_a[i]:offs_a[i + 1]]) ==
                    set(idx_b[offs_a[i]:offs_a[i + 1]])), i
    else:
        a = query(bvh, nearest(jnp.asarray(queries), 4))
        b = query(bvh, nearest(jnp.asarray(queries), 4), sort_queries=True)
        np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
        np.testing.assert_allclose(np.asarray(a.distances),
                                   np.asarray(b.distances), atol=1e-6)


def test_nearest_callback_protocol():
    """Nearest + callback: invoked per result in ascending-distance order."""
    rng = np.random.default_rng(23)
    pts = rng.uniform(0, 1, (40, 3)).astype(np.float32)
    bvh = _bvh(pts)
    k = 5

    def cb(carry, qi, j, dist):  # sum of the k best distances
        return carry + dist, jnp.bool_(False)

    got = np.asarray(query(bvh, nearest(jnp.asarray(pts), k), cb,
                           jnp.float32(0.0)))
    want = np.sort(np.sqrt(_d2(pts, pts)), axis=1)[:, :k].sum(1)
    np.testing.assert_allclose(got, want, atol=1e-4)
