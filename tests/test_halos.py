"""Halo-catalog subsystem vs the numpy oracle (labels -> production catalog)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import make_clustered_points
from repro.core.dbscan import fdbscan
from repro.core.ref_numpy import halo_catalog_ref
from repro.halos import (
    halo_catalog,
    merge_partial_catalogs,
    most_bound_centers,
    partial_catalog,
    so_masses,
)
from repro.halos.merge import finalize_rmax, local_rmax2, particle_slots
from repro.kernels import segment as kseg
from repro.kernels import ref as kref


def _phase_space(rng, n, **kw):
    pts = make_clustered_points(rng, n, **kw)
    vel = rng.standard_normal((n, 3)).astype(np.float32)
    return pts, vel


def _assert_catalog_matches_ref(cat, ref):
    assert int(cat.num_halos) == ref["num_halos"]
    assert bool(cat.overflow) == ref["overflow"]
    np.testing.assert_array_equal(np.asarray(cat.root), ref["root"])
    np.testing.assert_array_equal(np.asarray(cat.count), ref["count"])
    np.testing.assert_allclose(np.asarray(cat.mass), ref["mass"], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cat.center), ref["center"], atol=1e-5)
    np.testing.assert_allclose(np.asarray(cat.vmean), ref["vmean"], atol=1e-5)
    np.testing.assert_allclose(np.asarray(cat.vdisp), ref["vdisp"], atol=1e-4)
    np.testing.assert_allclose(np.asarray(cat.rmax), ref["rmax"], atol=1e-5)
    np.testing.assert_array_equal(np.asarray(cat.particle_halo),
                                  ref["particle_halo"])


# --- segment kernels vs oracles ----------------------------------------------

@pytest.mark.parametrize("n,s,d,tile", [(1000, 37, 8, 128), (130, 5, 3, 32),
                                        (50, 50, 1, 16), (700, 1, 4, 64)])
def test_segment_kernels_match_ref(rng, n, s, d, tile):
    sizes = rng.pareto(1.2, s).astype(int) + 1
    reps = np.repeat(np.arange(s), sizes)
    reps = (reps[:n] if len(reps) >= n
            else np.concatenate([reps, np.full(n - len(reps), s - 1)]))
    _, seg = np.unique(reps, return_inverse=True)   # sorted + dense
    num = int(seg.max()) + 1
    data = rng.standard_normal((n, d)).astype(np.float32)
    seg_j, data_j = jnp.asarray(seg, jnp.int32), jnp.asarray(data)
    np.testing.assert_allclose(
        np.asarray(kseg.segment_sum_sorted(data_j, seg_j, num, tile=tile)),
        np.asarray(kref.segment_sum_sorted_ref(data_j, seg_j, num)),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(kseg.segment_max_sorted(data_j, seg_j, num, tile=tile)),
        np.asarray(kref.segment_max_sorted_ref(data_j, seg_j, num)),
        rtol=1e-5, atol=1e-5)


# --- catalog vs numpy oracle --------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("min_pts", [2, 5])
def test_catalog_matches_ref_on_dbscan_labels(rng, backend, min_pts):
    pts, vel = _phase_space(rng, 400)
    labels = np.asarray(fdbscan(jnp.asarray(pts), 0.07, min_pts).labels)
    cat = halo_catalog(jnp.asarray(pts), jnp.asarray(vel),
                       jnp.asarray(labels), capacity=32, min_count=min_pts,
                       backend=backend)
    _assert_catalog_matches_ref(
        cat, halo_catalog_ref(pts, vel, labels, 32, min_pts))


def test_pallas_path_agrees_with_jax_path(rng):
    pts, vel = _phase_space(rng, 600)
    labels = np.asarray(fdbscan(jnp.asarray(pts), 0.07, 5).labels)
    a = halo_catalog(jnp.asarray(pts), jnp.asarray(vel), jnp.asarray(labels),
                     capacity=64, min_count=5, backend="jax")
    b = halo_catalog(jnp.asarray(pts), jnp.asarray(vel), jnp.asarray(labels),
                     capacity=64, min_count=5, backend="pallas")
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


def test_catalog_all_noise(rng):
    pts, vel = _phase_space(rng, 100)
    labels = np.full(100, -1, np.int32)
    cat = halo_catalog(jnp.asarray(pts), jnp.asarray(vel),
                       jnp.asarray(labels), capacity=8)
    assert int(cat.num_halos) == 0 and not bool(cat.overflow)
    assert (np.asarray(cat.particle_halo) == -1).all()
    assert (np.asarray(cat.count) == 0).all()
    assert (np.asarray(cat.root) == -1).all()


def test_catalog_single_giant_halo(rng):
    pts, vel = _phase_space(rng, 300)
    labels = np.zeros(300, np.int32)
    cat = halo_catalog(jnp.asarray(pts), jnp.asarray(vel),
                       jnp.asarray(labels), capacity=8)
    ref = halo_catalog_ref(pts, vel, labels, 8)
    _assert_catalog_matches_ref(cat, ref)
    assert int(cat.count[0]) == 300


def test_catalog_empty_halo_slots_and_mass_cut(rng):
    """Halos below min_count vanish; survivors compact in root order."""
    pts, vel = _phase_space(rng, 60)
    labels = np.array([0] * 30 + [40] * 3 + [50] * 20 + [-1] * 7, np.int32)
    cat = halo_catalog(jnp.asarray(pts), jnp.asarray(vel),
                       jnp.asarray(labels), capacity=8, min_count=5)
    assert int(cat.num_halos) == 2
    np.testing.assert_array_equal(np.asarray(cat.root)[:3], [0, 50, -1])
    np.testing.assert_array_equal(np.asarray(cat.count)[:3], [30, 20, 0])
    # cut halo's members map to no slot
    assert (np.asarray(cat.particle_halo)[30:33] == -1).all()
    _assert_catalog_matches_ref(cat, halo_catalog_ref(pts, vel, labels, 8, 5))


def test_catalog_capacity_overflow(rng):
    pts, vel = _phase_space(rng, 90)
    labels = np.repeat(np.arange(9) * 10, 10).astype(np.int32)
    cat = halo_catalog(jnp.asarray(pts), jnp.asarray(vel),
                       jnp.asarray(labels), capacity=4, min_count=2)
    ref = halo_catalog_ref(pts, vel, labels, 4, 2)
    assert bool(cat.overflow)
    _assert_catalog_matches_ref(cat, ref)


# --- most-bound centers / SO masses ------------------------------------------

def test_most_bound_center_is_member_and_argmin(rng):
    pts, vel = _phase_space(rng, 250)
    eps = 0.07
    labels = np.asarray(fdbscan(jnp.asarray(pts), eps, 5).labels)
    cat = halo_catalog(jnp.asarray(pts), jnp.asarray(vel),
                       jnp.asarray(labels), capacity=16, min_count=5)
    mb = most_bound_centers(jnp.asarray(pts), cat.particle_halo, eps,
                            capacity=16)
    ph = np.asarray(cat.particle_halo)
    soft2 = (eps * 1e-2) ** 2
    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    phi = -np.where(d2 <= eps * eps, 1.0 / np.sqrt(d2 + soft2), 0).sum(1)
    for h in range(int(cat.num_halos)):
        i = int(mb.index[h])
        assert ph[i] == h
        members = np.nonzero(ph == h)[0]
        assert phi[i] <= phi[members].min() + 1e-3
    for h in range(int(cat.num_halos), 16):
        assert int(mb.index[h]) == -1


def test_so_mass_uniform_ball():
    """Uniform-density ball: R_Δ is where the ball's density ratio crosses
    Δ — analytically checkable."""
    rng = np.random.default_rng(0)
    n = 4000
    r_ball = 0.1
    u = rng.uniform(0, 1, n) ** (1 / 3)
    direction = rng.standard_normal((n, 3))
    direction /= np.linalg.norm(direction, axis=1, keepdims=True)
    pts = (0.5 + r_ball * u[:, None] * direction).astype(np.float32)
    # ball density / mean box density = (n / (4/3 π r³)) / n = 1 / (4/3 π r³)
    ratio = 1.0 / (4.0 / 3.0 * np.pi * r_ball ** 3)
    delta = ratio / 8.0   # target crossing at R_Δ = r_ball (enclosed ∝ r³)
    centers = jnp.asarray(np.array([[0.5, 0.5, 0.5]], np.float32))
    so = so_masses(jnp.asarray(pts), centers, jnp.asarray([True]),
                   delta=delta, r_max=0.5, iters=24)
    # inside the ball density is flat at ratio > delta; outside it falls as
    # r^-3: crossing at r where ratio * (r_ball/r)^3 = delta -> r = 2 r_ball
    assert float(so.r_delta[0]) == pytest.approx(2 * r_ball, rel=0.05)
    assert int(so.count[0]) == n  # the whole ball is enclosed
    assert bool(so.bracketed[0])
    # too-small bracket: flagged unbracketed, R_Δ clamped near r_max
    so_clamped = so_masses(jnp.asarray(pts), centers, jnp.asarray([True]),
                           delta=delta, r_max=0.05, iters=24)
    assert not bool(so_clamped.bracketed[0])
    assert float(so_clamped.r_delta[0]) == pytest.approx(0.05, rel=1e-3)


# --- sharded merge == single-device ------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 4])
def test_merge_partials_equals_single_device(rng, n_shards):
    pts, vel = _phase_space(rng, 480)
    order = np.argsort(pts[:, 0], kind="stable")
    pts, vel = pts[order], vel[order]
    labels = np.asarray(fdbscan(jnp.asarray(pts), 0.07, 5).labels)
    cap = 32
    single = halo_catalog(jnp.asarray(pts), jnp.asarray(vel),
                          jnp.asarray(labels), capacity=cap, min_count=5)

    chunks = np.array_split(np.arange(len(pts)), n_shards)
    roots, sums = [], []
    for c in chunks:
        part = partial_catalog(jnp.asarray(pts[c]), jnp.asarray(vel[c]),
                               jnp.asarray(labels[c]), capacity=cap)
        roots.append(np.asarray(part.root))
        sums.append(np.asarray(part.sums))
    merged = merge_partial_catalogs(
        jnp.asarray(np.concatenate(roots)), jnp.asarray(np.concatenate(sums)),
        capacity=cap, min_count=5)
    rmax2 = jnp.full((cap,), -kseg.SEG_NEG_BIG)
    for c in chunks:
        rmax2 = jnp.maximum(rmax2, local_rmax2(jnp.asarray(pts[c]),
                                               jnp.asarray(labels[c]), merged))
    merged = finalize_rmax(merged, rmax2)

    assert int(merged.num_halos) == int(single.num_halos)
    for field in ("root", "count"):
        np.testing.assert_array_equal(np.asarray(getattr(merged, field)),
                                      np.asarray(getattr(single, field)))
    for field in ("mass", "center", "vmean", "vdisp", "rmax"):
        np.testing.assert_allclose(np.asarray(getattr(merged, field)),
                                   np.asarray(getattr(single, field)),
                                   atol=1e-4)
    # per-shard slot maps agree with the single-device particle map
    for c in chunks:
        np.testing.assert_array_equal(
            np.asarray(particle_slots(jnp.asarray(labels[c]), merged)),
            np.asarray(single.particle_halo)[c])


def test_sharded_catalog_on_mesh_matches_single_device():
    """shard_map driver == single device (subprocess: needs >1 CPU device)."""
    import os
    import subprocess
    import sys
    import textwrap

    tests_dir = os.path.dirname(os.path.abspath(__file__))
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        import sys
        sys.path.insert(0, {tests_dir!r})
        from conftest import make_clustered_points
        from repro.core.dbscan import fdbscan
        from repro.halos import halo_catalog, halo_catalog_sharded
        mesh = jax.make_mesh((4,), ("data",))
        rng = np.random.default_rng(3)
        n = 512
        pts = make_clustered_points(rng, n)
        pts = pts[np.argsort(pts[:, 0], kind="stable")]
        vel = rng.standard_normal((n, 3)).astype(np.float32)
        labels = fdbscan(jnp.asarray(pts), 0.07, 5).labels
        cap = 32
        single = halo_catalog(jnp.asarray(pts), jnp.asarray(vel), labels,
                              capacity=cap, min_count=5)
        sharded = halo_catalog_sharded(jnp.asarray(pts), jnp.asarray(vel),
                                       labels, mesh=mesh, capacity=cap,
                                       min_count=5)
        assert int(sharded.num_halos) == int(single.num_halos)
        np.testing.assert_array_equal(np.asarray(sharded.root),
                                      np.asarray(single.root))
        np.testing.assert_array_equal(np.asarray(sharded.count),
                                      np.asarray(single.count))
        for f in ("mass", "center", "vmean", "vdisp", "rmax"):
            np.testing.assert_allclose(np.asarray(getattr(sharded, f)),
                                       np.asarray(getattr(single, f)),
                                       atol=1e-4)
        np.testing.assert_array_equal(np.asarray(sharded.particle_halo),
                                      np.asarray(single.particle_halo))
        print("SHARDED_CAT_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(tests_dir), "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_CAT_OK" in out.stdout


# --- in-situ halo-stats mode --------------------------------------------------

def test_simulation_halo_stats_keys_and_finiteness(rng):
    from repro.analysis.insitu import InsituConfig, simulation_halo_stats
    pts, vel = _phase_space(rng, 300)
    stats = simulation_halo_stats(jnp.asarray(pts), jnp.asarray(vel),
                                  InsituConfig(min_pts=5, halo_min_count=5),
                                  0.07)
    assert set(stats) >= {"insitu/halo_num", "insitu/halo_largest",
                          "insitu/halo_mass_frac", "insitu/halo_vdisp_mean"}
    for v in stats.values():
        assert bool(jnp.all(jnp.isfinite(jnp.asarray(v, jnp.float32))))
    assert int(stats["insitu/halo_num"]) >= 1


def test_analyzer_simulation_mode(rng):
    from repro.analysis.insitu import InsituAnalyzer, InsituConfig
    pts, vel = _phase_space(rng, 300)
    an = InsituAnalyzer(InsituConfig(mode="simulation", cadence=1, min_pts=5,
                                     halo_min_count=5))
    out = an.maybe_run({"positions": jnp.asarray(pts),
                        "velocities": jnp.asarray(vel), "eps": 0.07}, 0)
    assert out and all(k.startswith("insitu/halo") for k in out)
