"""Observability layer (repro.obs): TraversalStats oracles vs brute force,
span tracer nesting + Chrome-trace round trip, and metrics-registry
aggregation (including per-shard columns from a shard_map region)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bvh import build_bvh
from repro.core.query import (
    nearest,
    query,
    query_count,
    query_csr_device,
    within,
)
from repro.obs import (
    MetricsRegistry,
    Span,
    SpanTracer,
    TraversalStats,
    load_chrome_trace,
    span_tree,
    traced,
)


def _bvh(pts):
    lo = pts.min(0) - 1e-4
    hi = pts.max(0) + 1e-4
    return build_bvh(jnp.asarray(pts), jnp.asarray(lo), jnp.asarray(hi))


def _points(n=257, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, 3)).astype(np.float32)


def _brute_counts(pts, eps):
    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1, dtype=np.float32)
    return (d2 <= np.float32(eps) ** 2).sum(1)


# --- TraversalStats oracles -------------------------------------------------

@pytest.mark.parametrize("backend", ["stackless", "stack"])
def test_stats_oracles_vs_bruteforce(backend):
    """callback_hits == brute-force pair counts; leaf_tests >= hits;
    nodes_visited == aabb_tests + leaf_tests (every loop iteration is
    exactly one bounding-volume test); counts identical to stats-off."""
    pts = _points()
    eps = 0.15
    bvh = _bvh(pts)
    want = _brute_counts(pts, eps)

    counts, stats = query_count(bvh, within(jnp.asarray(pts), eps),
                                backend=backend, with_stats=True)
    plain = query_count(bvh, within(jnp.asarray(pts), eps), backend=backend)
    np.testing.assert_array_equal(np.asarray(counts), want)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(plain))
    np.testing.assert_array_equal(np.asarray(stats.callback_hits), want)

    s = {k: np.asarray(v) for k, v in zip(TraversalStats._fields, stats)}
    assert np.all(s["leaf_tests"] >= s["callback_hits"])
    np.testing.assert_array_equal(
        s["nodes_visited"], s["aabb_tests"] + s["leaf_tests"])
    assert np.all(s["max_depth"] >= 1)
    # nothing terminates early without a short-circuiting callback
    assert not np.any(s["early_exits"])


@pytest.mark.parametrize("backend", ["stackless", "stack"])
def test_stats_early_exit_matches_shortcircuit(backend):
    """With stop_at=1 every query that has any neighbour (always true for a
    self-join: the query point itself) short-circuits, and the early-exit
    column says exactly which ones did."""
    pts = _points(n=128, seed=3)
    bvh = _bvh(pts)
    counts, stats = query_count(bvh, within(jnp.asarray(pts), 0.1),
                                stop_at=1, backend=backend, with_stats=True)
    want_exit = _brute_counts(pts, 0.1) >= 1
    np.testing.assert_array_equal(np.asarray(stats.early_exits), want_exit)
    assert np.all(np.asarray(counts) <= 1)
    # short-circuiting must visit no more nodes than the full traversal
    _, full = query_count(bvh, within(jnp.asarray(pts), 0.1),
                          backend=backend, with_stats=True)
    assert np.all(np.asarray(stats.nodes_visited)
                  <= np.asarray(full.nodes_visited))


def test_stats_pair_backend_half_counts():
    """Pair traversal visits each unordered pair once: total callback hits
    equal the brute-force pair count, and the invariants still hold."""
    pts = _points(n=96, seed=5)
    eps = 0.2
    bvh = _bvh(pts)

    def cb(c, qidx, obj, d2):
        return c + 1, jnp.bool_(False)

    out, stats = query(bvh, within(jnp.asarray(pts), eps), cb, jnp.int32(0),
                       backend="pair", with_stats=True)
    want_pairs = int((_brute_counts(pts, eps) - 1).sum()) // 2
    assert int(np.asarray(stats.callback_hits).sum()) == want_pairs
    s = {k: np.asarray(v) for k, v in zip(TraversalStats._fields, stats)}
    np.testing.assert_array_equal(
        s["nodes_visited"], s["aabb_tests"] + s["leaf_tests"])
    assert np.all(s["leaf_tests"] >= s["callback_hits"])


def test_stats_sort_queries_unsorts_stats_rows():
    """With engine-level Morton query sorting the stats rows must come back
    in ORIGINAL query order, aligned with the outputs."""
    pts = _points(n=200, seed=7)
    eps = 0.12
    bvh = _bvh(pts)
    counts, stats = query_count(bvh, within(jnp.asarray(pts), eps),
                                sort_queries=True, with_stats=True)
    want = _brute_counts(pts, eps)
    np.testing.assert_array_equal(np.asarray(counts), want)
    np.testing.assert_array_equal(np.asarray(stats.callback_hits), want)


def test_stats_compose_with_jit():
    pts = _points(n=64, seed=1)
    bvh = _bvh(pts)

    @jax.jit
    def run(p):
        return query_count(bvh, within(p, 0.2), with_stats=True)

    counts, stats = run(jnp.asarray(pts))
    np.testing.assert_array_equal(np.asarray(stats.callback_hits),
                                  _brute_counts(pts, 0.2))
    tot = stats.totals()
    assert int(tot["nodes_visited"]) == int(tot["aabb_tests"]) + int(tot["leaf_tests"])


def test_stats_rejects_priority_queue_protocols():
    pts = _points(n=32)
    bvh = _bvh(pts)
    with pytest.raises(ValueError, match="priority-queue"):
        query(bvh, nearest(jnp.asarray(pts), 4), with_stats=True)


# --- span tracer ------------------------------------------------------------

def test_tracer_nesting_and_roundtrip(tmp_path):
    tracer = SpanTracer(process_name="test")
    with tracer.span("outer", n=4) as sp:
        assert isinstance(sp, Span)
        with tracer.span("inner"):
            time.sleep(0.002)
        val = sp.fence(jnp.arange(8).sum())
    assert int(val) == 28
    tracer.instant("marker", step=1)
    tracer.counter("hits", total=3)

    path = tracer.export(str(tmp_path / "trace.json"))
    events = load_chrome_trace(path)
    assert [e["name"] for e in events] == ["outer", "inner"]
    tree = span_tree(events)
    assert tree["outer"] == ["inner"]
    outer = events[0]
    inner = events[1]
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"] == {"n": 4, "depth": 0}
    # non-span events survive the export (raw stream, not load_chrome_trace)
    import json
    raw = json.loads(open(path).read())["traceEvents"]
    assert {e["ph"] for e in raw} == {"M", "X", "i", "C"}


def test_traced_none_is_passthrough():
    calls = []

    def fn(x, y=1):
        calls.append((x, y))
        return x + y

    assert traced(None, "noop", fn, 2, y=3) == 5
    tracer = SpanTracer()
    assert traced(tracer, "yes", fn, 2, y=3, span_args={"k": 1}) == 5
    assert calls == [(2, 3), (2, 3)]
    assert tracer.events[0]["name"] == "yes"
    assert tracer.events[0]["args"]["k"] == 1


def test_tracer_exception_unwind():
    """A span that exits via exception still closes (no dangling stack) and
    skips its fences (no block_until_ready on the failure path)."""
    tracer = SpanTracer()
    with pytest.raises(RuntimeError):
        with tracer.span("outer"):
            with tracer.span("boom"):
                raise RuntimeError("x")
    assert [e["name"] for e in tracer.events] == ["boom", "outer"]
    assert tracer._stack == []


# --- metrics registry -------------------------------------------------------

def test_registry_aggregates_scalars_and_arrays():
    reg = MetricsRegistry()
    reg.record("x", 1)
    reg.record("x", np.array([2.0, 3.0]))
    reg.record("x", jnp.float32(4.0))
    s = reg.summary()["x"]
    assert s == {"records": 3, "count": 4, "sum": 10.0,
                 "min": 1.0, "max": 4.0, "last": 4.0}


def test_registry_observe_known_types(tmp_path):
    pts = _points(n=64, seed=2)
    bvh = _bvh(pts)
    csr = query_csr_device(bvh, within(jnp.asarray(pts), 0.2), capacity=4096)
    _, stats = query_count(bvh, within(jnp.asarray(pts), 0.2), with_stats=True)

    reg = MetricsRegistry()
    reg.observe("csr", csr)
    reg.observe("q", stats)
    s = reg.summary()
    assert s["csr/total"]["last"] == float(_brute_counts(pts, 0.2).sum())
    assert s["csr/overflowed"]["last"] == 0.0
    assert s["q/callback_hits"]["sum"] == float(_brute_counts(pts, 0.2).sum())
    assert s["q/nodes_visited"]["sum"] == (
        s["q/aabb_tests"]["sum"] + s["q/leaf_tests"]["sum"])
    out = reg.to_json(str(tmp_path / "metrics.json"))
    import json
    assert json.loads(open(out).read())["q/max_depth"]["last"] >= 1.0


def test_registry_shard_map_column():
    """Stats produced inside a shard_map region (with the cross-shard psum)
    aggregate in the registry to the same totals as the plain path."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    pts = _points(n=64, seed=4)
    bvh = _bvh(pts)
    try:
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except (AttributeError, TypeError):
        mesh = jax.make_mesh((1,), ("data",))

    def shard_fn(p):
        _, st = query_count(bvh, within(p, 0.2), with_stats=True)
        return st.psum("data")

    stats = shard_map(shard_fn, mesh=mesh, in_specs=P("data"),
                      out_specs=P("data"), check_rep=False)(jnp.asarray(pts))
    reg = MetricsRegistry()
    reg.observe("sharded", stats)
    s = reg.summary()
    assert s["sharded/callback_hits"]["last"] == float(
        _brute_counts(pts, 0.2).sum())
