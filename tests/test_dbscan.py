"""DBSCAN variants vs the Ester-semantics numpy oracle (paper §4.3)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dbscan import (
    NOISE,
    count_neighbors,
    dbscan_graph_cc,
    fdbscan,
    fdbscan_densebox,
    fdbscan_pair,
)
from repro.core.bvh import build_bvh
from repro.core.ref_numpy import core_mask_ref, dbscan_ref, labels_equivalent
from conftest import make_clustered_points

VARIANTS = {
    "graph_cc": lambda p, e, m: dbscan_graph_cc(p, e, m, neighbor_capacity=256),
    "fdbscan": lambda p, e, m: fdbscan(p, e, m),
    "fdbscan_stack": lambda p, e, m: fdbscan(p, e, m, use_stack=True),
    "fdbscan_32bit": lambda p, e, m: fdbscan(p, e, m, use_64bit=False),
    "fdbscan_pair": lambda p, e, m: fdbscan_pair(p, e, m, edge_capacity=4),
    "fdbscan_densebox": lambda p, e, m: fdbscan_densebox(p, e, m),
}


def _check(pts: np.ndarray, eps: float, min_pts: int, variant: str):
    ref = dbscan_ref(pts, eps, min_pts)
    core = core_mask_ref(pts, eps, min_pts)
    res = VARIANTS[variant](jnp.asarray(pts), eps, min_pts)
    np.testing.assert_array_equal(np.asarray(res.core_mask), core,
                                  err_msg=f"{variant}: core mask mismatch")
    assert labels_equivalent(np.asarray(res.labels), ref, core), \
        f"{variant}: cluster partition mismatch"


@pytest.mark.parametrize("variant", sorted(VARIANTS))
@pytest.mark.parametrize("min_pts", [2, 5, 10])
def test_variants_match_oracle_clustered(variant, min_pts, clustered_points):
    _check(clustered_points[:250], 0.05, min_pts, variant)


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_variants_match_oracle_uniform(variant):
    pts = np.random.default_rng(5).uniform(0, 1, (200, 3)).astype(np.float32)
    _check(pts, 0.08, 3, variant)


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_all_noise(variant):
    # Far-apart points, minPts > 1 cluster size -> everything is noise.
    pts = (np.arange(24, dtype=np.float32)[:, None] * np.array([[1, 0, 0]], np.float32))
    res = VARIANTS[variant](jnp.asarray(pts), 0.25, 3)
    assert (np.asarray(res.labels) == int(NOISE)).all()
    assert not np.asarray(res.core_mask).any()


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_single_cluster(variant):
    rng = np.random.default_rng(6)
    pts = rng.normal(0, 0.01, (50, 3)).astype(np.float32) + 0.5
    res = VARIANTS[variant](jnp.asarray(pts), 0.2, 5)
    labels = np.asarray(res.labels)
    assert (labels == labels[0]).all() and labels[0] != int(NOISE)


@pytest.mark.parametrize("min_pts", [2, 4])
@given(seed=st.integers(0, 2 ** 31 - 1), n=st.integers(20, 120))
@settings(max_examples=12, deadline=None)
def test_property_fdbscan_random(min_pts, seed, n):
    rng = np.random.default_rng(seed)
    pts = make_clustered_points(rng, n)
    _check(pts, 0.07, min_pts, "fdbscan")


@given(seed=st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_property_densebox_random(seed):
    rng = np.random.default_rng(seed)
    pts = make_clustered_points(rng, 150)
    _check(pts, 0.07, 5, "fdbscan_densebox")


@given(seed=st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_property_pair_random(seed):
    rng = np.random.default_rng(seed)
    pts = make_clustered_points(rng, 150)
    _check(pts, 0.07, 2, "fdbscan_pair")


def test_duplicate_points_exact_overlap():
    """Coincident points (worst-case Morton collapse) must cluster together."""
    pts = np.zeros((30, 3), np.float32) + 0.5
    pts[15:] += 0.4  # two coincident piles
    for variant in ("fdbscan", "fdbscan_densebox"):
        res = VARIANTS[variant](jnp.asarray(pts), 0.01, 2)
        labels = np.asarray(res.labels)
        assert (labels[:15] == labels[0]).all()
        assert (labels[15:] == labels[15]).all()
        assert labels[0] != labels[15]


def test_count_neighbors_early_termination_saturates(clustered_points):
    pts = jnp.asarray(clustered_points[:200])
    lo = pts.min(0) - 1e-4
    hi = pts.max(0) + 1e-4
    bvh = build_bvh(pts, lo, hi)
    full = np.asarray(count_neighbors(bvh, pts, pts, 0.05))
    sat = np.asarray(count_neighbors(bvh, pts, pts, 0.05, min_pts=5))
    assert (sat <= np.maximum(full, 5)).all()
    np.testing.assert_array_equal(sat >= 5, full >= 5)


def test_densebox_benchmark_regime_regression():
    """Regression: at benchmark density (HACC ε convention) DenseBox used to
    under-merge when a loose point with the SMALLER label sat within ε of a
    non-head dense member (one-directional hook asymmetry)."""
    from repro.data.pipeline import hacc_benchmark_epsilon, make_clustered_points
    pts = make_clustered_points(np.random.default_rng(0), 512)
    eps = hacc_benchmark_epsilon(1.0, 512)
    a = fdbscan(jnp.asarray(pts), eps, 2)
    b = fdbscan_densebox(jnp.asarray(pts), eps, 2)
    core = np.asarray(a.core_mask)
    np.testing.assert_array_equal(np.asarray(b.core_mask), core)
    assert labels_equivalent(np.asarray(b.labels), np.asarray(a.labels), core)


def test_eps_zero_all_noise_minpts2():
    pts = np.random.default_rng(7).uniform(0, 1, (40, 3)).astype(np.float32)
    res = fdbscan(jnp.asarray(pts), 1e-9, 2)
    assert (np.asarray(res.labels) == int(NOISE)).all()


def test_minpts_one_is_all_core_each_point_cluster():
    pts = (np.arange(10, dtype=np.float32)[:, None] * np.array([[1, 0, 0]], np.float32))
    res = fdbscan(jnp.asarray(pts), 0.1, 1)
    labels = np.asarray(res.labels)
    np.testing.assert_array_equal(labels, np.arange(10))
