"""Morton code unit + property tests (paper §4.2.2, Table 1)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import morton


def _ref_expand3(v: int) -> int:
    out = 0
    for bit in range(21):
        out |= ((v >> bit) & 1) << (3 * bit)
    return out


def _ref_morton3(x: int, y: int, z: int, bits: int) -> int:
    m = (1 << bits) - 1
    return (_ref_expand3(x & m) << 2) | (_ref_expand3(y & m) << 1) | _ref_expand3(z & m)


def test_morton32_matches_bitwise_reference():
    rng = np.random.default_rng(0)
    q = rng.integers(0, 1024, (256, 3))
    unit = (q.astype(np.float64) + 0.5) / 1024.0
    got = np.asarray(morton.morton32(jnp.asarray(unit, jnp.float32)))
    want = np.array([_ref_morton3(*row, bits=10) for row in q], np.uint32)
    np.testing.assert_array_equal(got, want)


def test_morton64_matches_bitwise_reference():
    rng = np.random.default_rng(1)
    q = rng.integers(0, 1 << 21, (256, 3))
    unit = (q.astype(np.float64) + 0.5) / float(1 << 21)
    hi, lo = morton.morton64(jnp.asarray(unit, jnp.float32))
    got = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) | np.asarray(lo).astype(np.uint64)
    # float32 quantization: recompute the quantized coordinate the kernel saw.
    q32 = np.floor(np.asarray(unit, np.float32) * float(1 << 21)).astype(np.int64)
    q32 = np.clip(q32, 0, (1 << 21) - 1)
    want = np.array([_ref_morton3(*row, bits=21) for row in q32], np.uint64)
    np.testing.assert_array_equal(got, want)


@given(st.lists(st.tuples(st.floats(0, 0.999999), st.floats(0, 0.999999), st.floats(0, 0.999999)),
                min_size=2, max_size=64))
@settings(max_examples=50, deadline=None)
def test_morton64_order_refines_morton32(coords):
    """Property: the 64-bit code order is a refinement of the 32-bit order —
    if code32(a) < code32(b), then code64(a) < code64(b)."""
    pts = jnp.asarray(np.array(coords, np.float32))
    c32 = np.asarray(morton.morton32(pts)).astype(np.uint64)
    hi, lo = morton.morton64(pts)
    c64 = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) | np.asarray(lo).astype(np.uint64)
    for i in range(len(coords)):
        for j in range(len(coords)):
            if c32[i] < c32[j]:
                assert c64[i] < c64[j]


def test_table1_collision_phenomenon(clustered_points):
    """Paper Table 1: clustered data collides massively at 32 bits, ~never at
    64 bits."""
    pts = jnp.asarray(clustered_points)
    lo = pts.min(0) - 1e-5
    hi = pts.max(0) + 1e-5
    unit = morton.normalize_points(pts, lo, hi)

    c32 = np.asarray(morton.morton32(unit))
    h, l = morton.morton64(unit)
    c64 = (np.asarray(h).astype(np.uint64) << np.uint64(32)) | np.asarray(l).astype(np.uint64)

    def dup_count(codes):
        _, counts = np.unique(codes, return_counts=True)
        return int(counts[counts > 1].sum())

    assert dup_count(c64) <= dup_count(c32)


def test_common_prefix_length_tie_break():
    codes = jnp.asarray([5, 5, 5, 9], jnp.uint32)
    i = jnp.asarray([0, 0, 0])
    j = jnp.asarray([1, 2, 3])
    d = morton.common_prefix_length32(codes, i, j)
    # Equal codes: 32 + clz(i ^ j) > 32; distinct codes: < 32.
    assert int(d[0]) > 32 and int(d[1]) > 32 and int(d[2]) < 32
    # Closer indices share longer prefixes.
    assert int(d[0]) > int(d[1])


def test_common_prefix_out_of_range():
    codes = jnp.asarray([1, 2, 3], jnp.uint32)
    assert int(morton.common_prefix_length32(codes, jnp.int32(0), jnp.int32(-1))) == -1
    assert int(morton.common_prefix_length32(codes, jnp.int32(0), jnp.int32(3))) == -1


def test_sort64_is_lexicographic():
    rng = np.random.default_rng(3)
    hi = jnp.asarray(rng.integers(0, 4, 128), jnp.uint32)
    lo = jnp.asarray(rng.integers(0, 1 << 30, 128), jnp.uint32)
    perm = np.asarray(morton.sort_by_morton64(hi, lo))
    keys = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) | np.asarray(lo).astype(np.uint64)
    np.testing.assert_array_equal(keys[perm], np.sort(keys))
