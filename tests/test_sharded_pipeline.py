"""End-to-end sharded pipeline tests (subprocess: the multi-device XLA flag
must be set before jax imports).

Covers the reusable sharded-query layer (``sharded_neighbor_csr``: per-shard
BVH build → ppermute ghost exchange → device-resident CSR with GLOBAL ids)
and the one-region fused pipeline (``halo_pipeline_sharded``: build →
exchange → DBSCAN → catalog merge → SO masses), including the acceptance
check that the fused pipeline performs ZERO device→host transfers after
warmup (``repro.staticcheck.assert_no_host_transfers(..., guard="d2h")``).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap


def _run(code: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


_PRELUDE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
    import numpy as np, jax, jax.numpy as jnp
    try:  # axis_types only exists on newer JAX
        mesh = jax.make_mesh(({n},), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except (AttributeError, TypeError):
        mesh = jax.make_mesh(({n},), ("data",))
""")


def test_sharded_neighbor_csr_matches_oracle():
    """Global-id CSR rows from the sharded layer == brute-force ε-graph."""
    code = _PRELUDE.format(n=4) + textwrap.dedent("""
        from repro.core.distributed import sharded_neighbor_csr, slab_partition

        rng = np.random.default_rng(3)
        pts = rng.uniform(0, 1, (256, 3)).astype(np.float32)
        pts, _ = slab_partition(pts, 4)
        eps = 0.12
        res = sharded_neighbor_csr(jnp.asarray(pts), eps, capacity=4096,
                                   mesh=mesh, halo_cap=128)
        assert not bool(res.overflowed), "capacity overflow"
        offs = np.asarray(res.offsets)          # (4, n_loc+1)
        idx = np.asarray(res.indices)           # (4, capacity) global ids
        n_loc = offs.shape[1] - 1

        d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
        adj = d2 <= eps * eps                   # self included
        for s in range(4):
            for q in range(n_loc):
                got = np.sort(idx[s, offs[s, q]:offs[s, q + 1]])
                want = np.flatnonzero(adj[s * n_loc + q])
                assert (got == want).all(), (s, q, got, want)
        total = int(np.asarray(res.total).sum())
        assert total == int(adj.sum())
        print("CSR_OK")
    """)
    assert "CSR_OK" in _run(code)


def test_halo_pipeline_matches_staged_path():
    """Fused one-region pipeline == staged dbscan_ref + single-node catalog,
    and the SO-mass stage brackets real halos."""
    code = _PRELUDE.format(n=4) + textwrap.dedent("""
        import sys
        sys.path.insert(0, {tests!r})
        from conftest import make_clustered_points
        from repro.core.distributed import slab_partition
        from repro.core.ref_numpy import (core_mask_ref, dbscan_ref,
                                          labels_equivalent)
        from repro.halos import halo_catalog, halo_pipeline_sharded

        rng = np.random.default_rng(7)
        pts = make_clustered_points(rng, 512)
        pts, _ = slab_partition(pts, 4)
        vel = rng.standard_normal((512, 3)).astype(np.float32)
        eps = 0.05
        pipe = halo_pipeline_sharded(
            jnp.asarray(pts), jnp.asarray(vel), eps, 2, mesh=mesh,
            capacity=128, halo_cap=512, min_count=5, so_delta=200.0)
        assert not bool(pipe.halo_overflow)

        ref = dbscan_ref(pts, eps, 2)
        core = core_mask_ref(pts, eps, 2)
        labels = np.asarray(pipe.labels)
        assert (np.asarray(pipe.core_mask) == core).all(), "core mask"
        assert labels_equivalent(labels, ref, core), "labels"

        single = halo_catalog(jnp.asarray(pts), jnp.asarray(vel),
                              pipe.labels, capacity=128, min_count=5)
        assert int(pipe.catalog.num_halos) == int(single.num_halos)
        nh = int(single.num_halos)
        np.testing.assert_allclose(np.asarray(pipe.catalog.center)[:nh],
                                   np.asarray(single.center)[:nh], atol=1e-5)
        np.testing.assert_allclose(np.asarray(pipe.catalog.count)[:nh],
                                   np.asarray(single.count)[:nh])
        np.testing.assert_allclose(np.asarray(pipe.catalog.rmax)[:nh],
                                   np.asarray(single.rmax)[:nh], atol=1e-5)
        assert int(np.asarray(pipe.so.bracketed)[:nh].sum()) > 0
        print("PIPE_OK", nh)
    """).format(tests=os.path.dirname(os.path.abspath(__file__)))
    assert "PIPE_OK" in _run(code)


def test_halo_pipeline_zero_host_round_trips():
    """After warmup, the whole build→exchange→DBSCAN→catalog chain runs with
    device→host transfers DISALLOWED — the one-shard_map-region guarantee."""
    code = _PRELUDE.format(n=2) + textwrap.dedent("""
        from repro.core.distributed import slab_partition
        from repro.halos import halo_pipeline_sharded
        from repro.staticcheck import assert_no_host_transfers

        rng = np.random.default_rng(1)
        pts = rng.uniform(0, 1, (128, 3)).astype(np.float32)
        pts, _ = slab_partition(pts, 2)
        vel = rng.standard_normal((128, 3)).astype(np.float32)
        jp, jv = jnp.asarray(pts), jnp.asarray(vel)

        run = lambda: halo_pipeline_sharded(jp, jv, 0.08, 2, mesh=mesh,
                                            capacity=128, halo_cap=64,
                                            min_count=2)
        # warmup runs outside the guard; the guarded rerun is the contract
        out = assert_no_host_transfers(run, guard="d2h")
        assert int(out.catalog.num_halos) >= 1
        print("GUARD_OK")
    """)
    assert "GUARD_OK" in _run(code)
