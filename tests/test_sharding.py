"""Sharding-rule unit tests (these don't need >1 device: PartitionSpec
construction is pure logic)."""
from __future__ import annotations

import numpy as np
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.spec import TensorSpec
from repro.parallel import sharding as shd


from conftest import abstract_mesh


@pytest.fixture(scope="module")
def meshes():
    # 1-device meshes can't test divisibility; build ABSTRACT meshes instead.
    single = abstract_mesh((16, 16), ("data", "model"))
    multi = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return single, multi


def test_fsdp_tp_param_layout(meshes):
    single, multi = meshes
    wq = TensorSpec((4096, 64, 128), ("embed", "heads", "qkv"))
    assert shd.pspec_for(wq, single) == P("data", "model", None)
    assert shd.pspec_for(wq, multi) == P(("pod", "data"), "model", None)


def test_divisibility_guard_drops_axis(meshes):
    single, _ = meshes
    # kv=1 (MQA): cannot shard 1 over 16 -> replicated
    wk = TensorSpec((4096, 1, 128), ("embed", "kv", "qkv"))
    assert shd.pspec_for(wk, single) == P("data", None, None)
    # kv=8 over model=16: not divisible -> dropped
    wk8 = TensorSpec((4096, 8, 128), ("embed", "kv", "qkv"))
    assert shd.pspec_for(wk8, single) == P("data", None, None)


def test_axis_tuple_prefix_fit(meshes):
    _, multi = meshes
    # embed rows divisible by pod(2) but not pod*data(32): prefix ("pod",)
    w = TensorSpec((2 * 7, 64), ("embed", "mlp"))
    assert shd.pspec_for(w, multi) == P("pod", "model")


def test_mesh_axis_used_once(meshes):
    single, _ = meshes
    # both dims want "model": second one must drop it
    w = TensorSpec((64, 128), ("heads", "mlp"))
    spec = shd.pspec_for(w, single)
    used = [e for e in spec if e is not None]
    assert len(used) == len(set(used)) == 1


def test_expert_sharding(meshes):
    single, _ = meshes
    wi = TensorSpec((64, 2048, 1408), ("experts", "embed", "mlp"))
    assert shd.pspec_for(wi, single) == P("model", "data", None)


def test_data_pspec(meshes):
    single, multi = meshes
    assert shd.data_pspec(single, 256, 2) == P("data", None)
    assert shd.data_pspec(multi, 256, 2) == P(("pod", "data"), None)
    # batch=1: not divisible -> replicated
    assert shd.data_pspec(multi, 1, 2) == P(None, None)


def test_cache_pspec_stacked_layout(meshes):
    single, _ = meshes
    # (G=21, B=128, S=32768, kv=8, hd=256): batch dim1 over data, seq/model
    spec = shd.cache_pspec(single, (21, 128, 32768, 8, 256), batch_dim=1)
    assert spec == P(None, "data", "model", None, None)
    # layer0 (B, S, kv, hd): batch dim0
    spec0 = shd.cache_pspec(single, (128, 32768, 16, 128), batch_dim=0)
    assert spec0 == P("data", "model", None, None)


def test_cache_pspec_b1_long_context(meshes):
    single, _ = meshes
    # long_500k: B=1 unshardable; seq must take "model"
    spec = shd.cache_pspec(single, (9, 1, 524288, 8, 128), batch_dim=1)
    assert spec == P(None, None, "model", None, None)


def test_score_pspec_choice(meshes):
    single, _ = meshes
    assert shd.default_score_pspec(single, 64) == P("data", "model", None, None)
    assert shd.default_score_pspec(single, 40) == P("data", None, "model", None)


def test_decode_score_pspec(meshes):
    single, _ = meshes
    assert shd.decode_score_pspec(single) == P("data", None, None, "model")


def test_param_pspecs_tree():
    from repro.configs import get_config
    from repro.models import lm
    mesh = abstract_mesh((16, 16), ("data", "model"))
    spec = lm.model_spec(get_config("gemma2-9b"))
    pspecs = shd.param_pspecs(spec, mesh)
    # embed (256000, 3584): vocab/model, embed/data
    assert pspecs["embed"] == P("model", "data")
    # every leaf produced a PartitionSpec
    assert all(isinstance(p, P) for p in jax.tree.leaves(
        pspecs, is_leaf=lambda x: isinstance(x, P)))
