"""Dry-run planning logic (no compilation, abstract meshes)."""
from __future__ import annotations

import numpy as np
import jax
import pytest

# NOTE: importing repro.launch.dryrun sets XLA_FLAGS; harmless here because
# jax is already initialized with 1 device by the time tests import it.
from conftest import abstract_mesh
from repro.configs import ARCH_IDS, SHAPES, get_config, shapes_for
from repro.launch import dryrun as dr


@pytest.fixture(scope="module")
def mesh():
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.fixture(scope="module")
def multi_mesh():
    return abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_input_specs_shapes():
    cfg = get_config("gemma2-9b")
    b = dr.input_specs(cfg, SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096)
    assert b["labels"].shape == (256, 4096)
    d = dr.input_specs(cfg, SHAPES["decode_32k"])
    assert d["tokens"].shape == (128, 1)


def test_input_specs_modality_stubs():
    vlm = dr.input_specs(get_config("llama-3.2-vision-11b"), SHAPES["train_4k"])
    assert vlm["vision"].shape == (256, 1601, 7680)
    aud = dr.input_specs(get_config("seamless-m4t-large-v2"), SHAPES["train_4k"])
    assert aud["frames"].shape == (256, 1024, 1024)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_plan_state_fits(arch, mesh):
    """Every arch's training state (params+opt+grads) must fit the plan."""
    cfg = get_config(arch)
    plan = dr.train_plan(cfg, SHAPES["train_4k"], mesh)
    params_b = plan["params_b"]
    grad_mult = 1 if plan["grad_dtype"] == "bfloat16" else 2
    state = params_b * (3 + grad_mult)
    assert state < 15e9, (arch, state / 1e9)
    assert plan["accum"] >= 1
    assert plan["rows"] * plan["accum"] * 16 == SHAPES["train_4k"].global_batch


def test_jamba_uses_bf16_grads(mesh):
    plan = dr.train_plan(get_config("jamba-1.5-large-398b"),
                         SHAPES["train_4k"], mesh)
    assert plan["grad_dtype"] == "bfloat16"


def test_small_models_keep_f32_grads(mesh):
    plan = dr.train_plan(get_config("gemma2-9b"), SHAPES["train_4k"], mesh)
    assert plan["grad_dtype"] == "float32"


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_memory_model_all_cells_fit(arch, mesh_kind, mesh, multi_mesh):
    m = mesh if mesh_kind == "single" else multi_mesh
    cfg = get_config(arch)
    for shape in shapes_for(cfg):
        mm = dr.memory_model(cfg, shape, m)
        assert mm["fits_16GB"], (arch, shape.name, mesh_kind,
                                 {k: round(v / 1e9, 2) for k, v in mm.items()
                                  if isinstance(v, float)})


def test_shapes_for_rules():
    assert len(shapes_for(get_config("gemma2-9b"))) == 3      # no long_500k
    assert len(shapes_for(get_config("jamba-1.5-large-398b"))) == 4


def test_model_flops_moe_uses_active_params(mesh):
    dense = dr.model_flops(get_config("gemma2-9b"), SHAPES["train_4k"])
    # 6 * 9.24e9 * 256*4096 within 1%
    assert abs(dense - 6 * 9.242e9 * 256 * 4096) / dense < 0.01
    moe = dr.model_flops(get_config("qwen3-moe-235b-a22b"), SHAPES["train_4k"])
    # active ~22B, not 235B
    assert moe < 6 * 40e9 * 256 * 4096


def test_collective_bytes_parser():
    hlo = """
      %ag = bf16[64,128]{1,0} all-gather(%x), replica_groups=[2,4]<=[8]
      %ar = f32[16]{0} all-reduce(%y), to_apply=%add
    """
    out = dr.collective_bytes(hlo)
    assert out["all-gather"] == 64 * 128 * 2
    assert out["all-reduce"] == 16 * 4 * 2
