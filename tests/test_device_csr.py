"""Device-resident CSR protocol (scan-then-scatter, no host sync) + the
all-hits ray protocol riding on it.

The contract under test (ISSUE 6 / ArborX 2.0's count-then-fill backbone):
  - `query_csr_device` is jit-traceable end to end with a static capacity;
    no Python-level sync between the count and fill passes;
  - staging memory is O(q·chunk + capacity), NEVER the dense
    (q, max_count) buffer the old fill used — checked on a SKEWED workload
    (one query matches every leaf, the rest match none) by walking the
    jaxpr for intermediate shapes;
  - the dynamic path (`capacity=None`) performs exactly one documented
    sizing sync and returns an exactly-sized result.
"""
from __future__ import annotations

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.bvh import build_bvh, build_bvh_objects
from repro.core.geometry import scene_bounds
from repro.core.query import (query, query_csr, query_csr_buffered,
                              query_csr_device, ray, within)
from repro.core.raycast import raycast, raycast_all
from repro.staticcheck import (assert_no_host_transfers, audit_jaxpr,
                               max_intermediate_elems, no_dense_intermediate)


def _bvh(pts):
    jp = jnp.asarray(pts)
    lo, hi = scene_bounds(jp)
    return build_bvh(jp, lo, hi)


def _d2(a, b):
    return ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)


def _rows(offs, idx, q):
    return [frozenset(idx[offs[i]:offs[i + 1]].tolist()) for i in range(q)]


def _skewed(n=128, nq=64):
    """One fat query covering the whole unit cube, the rest far away."""
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    queries = np.full((nq, 3), 50.0, np.float32)  # match nothing
    queries[0] = 0.5
    radii = np.full((nq,), 1e-3, np.float32)
    radii[0] = 2.0                                # match EVERYTHING
    return pts, queries, radii


# --- correctness: skewed + property tests vs the oracle ----------------------

def test_skewed_neighborhoods_match_oracle():
    pts, queries, radii = _skewed()
    bvh = _bvh(pts)
    pred = within(jnp.asarray(queries), jnp.asarray(radii))
    adj = _d2(queries, pts) <= radii[:, None] ** 2
    assert adj[0].all() and not adj[1:].any()  # the skew is real

    for backend in ("stackless", "stack"):
        res = query_csr_device(bvh, pred, capacity=len(pts) + 8,
                               backend=backend)
        assert not bool(res.overflowed)
        assert int(res.total) == int(adj.sum())
        offs, idx = np.asarray(res.offsets), np.asarray(res.indices)
        np.testing.assert_array_equal(np.diff(offs), adj.sum(1))
        got = _rows(offs, idx, len(queries))
        want = [frozenset(np.nonzero(adj[i])[0].tolist())
                for i in range(len(queries))]
        assert got == want, backend
        # padding past total is the sentinel
        assert (idx[int(res.total):] == -1).all()


def test_skewed_staging_memory_is_not_dense():
    """Audit the jaxpr of the jitted device path: no intermediate may be
    (q × max_count)-sized — the scan-then-scatter replaces the dense fill.
    (The walker that used to live here is now repro.staticcheck.)"""
    pts, queries, radii = _skewed(n=256, nq=256)
    bvh = _bvh(pts)
    pred = within(jnp.asarray(queries), jnp.asarray(radii))
    q, max_count = len(queries), len(pts)   # densest query hits every leaf
    chunk = 16
    capacity = max_count + 64
    dense_elems = q * max_count             # 65536 — the forbidden budget

    fn = lambda b, p: query_csr_device(b, p, capacity, chunk=chunk)
    biggest = max_intermediate_elems(fn, (bvh, pred))
    assert biggest > 0                       # the walker actually saw arrays
    findings = audit_jaxpr(fn, (bvh, pred),
                           [no_dense_intermediate(dense_elems)],
                           name="query_csr_device")
    assert findings == [], [str(f) for f in findings]


@given(n=st.integers(2, 50), nq=st.integers(0, 40),
       eps=st.floats(0.0, 0.6), chunk=st.sampled_from([1, 3, 32]),
       seed=st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_device_csr_property_vs_oracle(n, nq, eps, chunk, seed):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    queries = rng.uniform(-0.1, 1.1, (nq, 3)).astype(np.float32)
    bvh = _bvh(pts)
    pred = within(jnp.asarray(queries), eps)
    adj = _d2(queries, pts) <= np.float32(eps) ** 2
    res = query_csr_device(bvh, pred, capacity=int(adj.sum()) + 4, chunk=chunk)
    assert not bool(res.overflowed)
    offs, idx = np.asarray(res.offsets), np.asarray(res.indices)
    np.testing.assert_array_equal(np.diff(offs), adj.sum(1))
    assert _rows(offs, idx, nq) == [
        frozenset(np.nonzero(adj[i])[0].tolist()) for i in range(nq)]


# --- edge cases --------------------------------------------------------------

def test_csr_empty_predicates():
    """Zero queries used to crash the sizing pass (max over empty counts)."""
    pts = np.random.default_rng(0).uniform(0, 1, (16, 3)).astype(np.float32)
    bvh = _bvh(pts)
    pred = within(jnp.zeros((0, 3), jnp.float32), 0.1)

    res = query_csr(bvh, pred)
    assert res.offsets.shape == (1,) and int(res.offsets[0]) == 0
    assert res.indices.shape == (0,) and int(res.total) == 0

    dev = query_csr_device(bvh, pred, capacity=4)
    assert dev.offsets.shape == (1,) and int(dev.total) == 0
    assert (np.asarray(dev.indices) == -1).all()

    buf = query_csr_buffered(bvh, pred, capacity=2)
    assert buf.indices.shape[0] == 0 and buf.attempts == 1


def test_device_csr_overflow_flagged_and_truncated():
    pts, queries, radii = _skewed(n=64, nq=8)
    bvh = _bvh(pts)
    pred = within(jnp.asarray(queries), jnp.asarray(radii))
    res = query_csr_device(bvh, pred, capacity=10)
    assert bool(res.overflowed)
    assert int(res.total) == 64                  # true total, not clamped
    idx = np.asarray(res.indices)
    assert idx.shape == (10,) and (idx >= 0).all()
    assert set(idx.tolist()) <= set(range(64))   # a prefix of query 0's hits


# --- jit traceability / no host sync -----------------------------------------

def test_device_csr_jit_traces_without_sync():
    """jax.jit(query_csr_device) must trace (no concretization errors — i.e.
    no int()/.item() between count and fill) and, once compiled, run under
    ``jax.transfer_guard("disallow")`` with zero host transfers — the
    warm-up-then-guard dance lives in staticcheck's runtime helper."""
    pts, queries, radii = _skewed(n=64, nq=32)
    bvh = _bvh(pts)
    qd = jax.device_put(jnp.asarray(queries))
    rd = jax.device_put(jnp.asarray(radii))

    @jax.jit
    def run(bvh, q, r):
        return query_csr_device(bvh, within(q, r), capacity=96)

    res = assert_no_host_transfers(run, bvh, qd, rd)
    assert int(res.total) == 64

    # the dynamic path, by contrast, performs its one documented sizing sync
    with jax.transfer_guard("disallow"):
        with pytest.raises(Exception):
            query_csr(bvh, within(qd, rd))


# --- all-hits ray protocol ---------------------------------------------------

def _boxed_scene(n=40, seed=5):
    rng = np.random.default_rng(seed)
    lo = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    hi = lo + rng.uniform(0.05, 0.2, (n, 3)).astype(np.float32)
    return lo, hi


def _ray_box_oracle(o, d, lo, hi):
    """Numpy slab test: does ray o + t·d (t ≥ 0) hit box [lo, hi]?"""
    with np.errstate(divide="ignore", invalid="ignore"):
        inv = np.where(d != 0, 1.0 / d, np.inf)
    t0 = (lo - o) * inv
    t1 = (hi - o) * inv
    near = np.minimum(t0, t1)
    far = np.maximum(t0, t1)
    # zero direction components: inside the slab iff lo <= o <= hi
    inside = (d == 0) & (o >= lo) & (o <= hi)
    near = np.where(d == 0, np.where(inside, -np.inf, np.inf), near)
    far = np.where(d == 0, np.where(inside, np.inf, -np.inf), far)
    tmin = np.maximum(near.max(-1), 0.0)
    tmax = far.min(-1)
    return tmin <= tmax


def test_raycast_all_matches_slab_oracle():
    lo, hi = _boxed_scene()
    slo, shi = scene_bounds(jnp.asarray(np.concatenate([lo, hi])))
    bvh = build_bvh_objects(jnp.asarray(lo), jnp.asarray(hi), slo, shi)

    rng = np.random.default_rng(7)
    origins = rng.uniform(-0.5, 1.5, (25, 3)).astype(np.float32)
    dirs = rng.normal(size=(25, 3)).astype(np.float32)

    res = raycast_all(bvh, jnp.asarray(origins), jnp.asarray(dirs))
    offs, idx = np.asarray(res.offsets), np.asarray(res.indices)
    want = np.stack([_ray_box_oracle(origins[i], dirs[i], lo, hi)
                     for i in range(len(origins))])
    np.testing.assert_array_equal(np.diff(offs), want.sum(1))
    assert _rows(offs, idx, len(origins)) == [
        frozenset(np.nonzero(want[i])[0].tolist())
        for i in range(len(origins))]


def test_raycast_all_device_capacity_and_nearest_consistency():
    lo, hi = _boxed_scene(n=30, seed=11)
    slo, shi = scene_bounds(jnp.asarray(np.concatenate([lo, hi])))
    bvh = build_bvh_objects(jnp.asarray(lo), jnp.asarray(hi), slo, shi)

    rng = np.random.default_rng(13)
    origins = rng.uniform(-0.5, 1.5, (16, 3)).astype(np.float32)
    dirs = rng.normal(size=(16, 3)).astype(np.float32)
    o, d = jnp.asarray(origins), jnp.asarray(dirs)

    # device path under jit agrees with the dynamic path
    run = jax.jit(lambda: raycast_all(bvh, o, d, capacity=512))
    dev = run()
    dyn = raycast_all(bvh, o, d)
    assert not bool(dev.overflowed)
    np.testing.assert_array_equal(np.asarray(dev.offsets),
                                  np.asarray(dyn.offsets))
    offs = np.asarray(dyn.offsets)
    di, yi = np.asarray(dev.indices), np.asarray(dyn.indices)
    assert _rows(offs, di, 16) == _rows(offs, yi, 16)

    # every nearest hit is among that ray's all-hits row
    near = raycast(bvh, o, d)
    ni = np.asarray(near.index)
    rows = _rows(offs, yi, 16)
    for i in range(16):
        if ni[i] >= 0:
            assert ni[i] in rows[i], i
        else:
            assert not rows[i], i
