"""LBVH structural invariants + traversal correctness (paper §4.2)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bvh import build_bvh, SENTINEL
from repro.core.traversal import (
    pair_traverse_sphere,
    traverse_sphere_stack,
    traverse_sphere_stackless,
)


def _build(pts):
    lo = pts.min(0) - 1e-4
    hi = pts.max(0) + 1e-4
    return build_bvh(jnp.asarray(pts), jnp.asarray(lo), jnp.asarray(hi))


def _rand(n, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, 3)).astype(np.float32)


@pytest.mark.parametrize("n", [2, 3, 7, 64, 257])
@pytest.mark.parametrize("use_64bit", [False, True])
def test_bvh_structure(n, use_64bit):
    pts = _rand(n, seed=n)
    lo = pts.min(0) - 1e-4
    hi = pts.max(0) + 1e-4
    bvh = build_bvh(jnp.asarray(pts), jnp.asarray(lo), jnp.asarray(hi), use_64bit=use_64bit)

    left = np.asarray(bvh.left_child)
    right = np.asarray(bvh.right_child)
    # Every node except the root has exactly one parent.
    seen = np.concatenate([left, right])
    counts = np.bincount(seen, minlength=2 * n - 1)
    assert counts[0] == 0  # root
    assert (counts[1:] == 1).all()

    # perm is a permutation.
    np.testing.assert_array_equal(np.sort(np.asarray(bvh.leaf_perm)), np.arange(n))

    # Internal AABBs contain children AABBs.
    nlo, nhi = np.asarray(bvh.node_lo), np.asarray(bvh.node_hi)
    for i in range(n - 1):
        for c in (left[i], right[i]):
            assert (nlo[i] <= nlo[c] + 1e-6).all()
            assert (nhi[i] >= nhi[c] - 1e-6).all()

    # Root AABB covers the scene.
    assert (nlo[0] <= pts.min(0) + 1e-6).all() and (nhi[0] >= pts.max(0) - 1e-6).all()


@pytest.mark.parametrize("n", [2, 5, 64, 130])
def test_ropes_visit_all_leaves_in_order(n):
    """Following left-child on every internal node and ropes otherwise must
    enumerate all leaves exactly once, left to right — the rope invariant."""
    pts = _rand(n, seed=n + 1)
    bvh = _build(pts)
    left = np.asarray(bvh.left_child)
    rope = np.asarray(bvh.rope)
    node, seen = 0, []
    while node != int(SENTINEL):
        if node >= n - 1:
            seen.append(node - (n - 1))
            node = int(rope[node])
        else:
            node = int(left[node])  # always "hit"
        assert len(seen) <= n
    assert seen == list(range(n))


@pytest.mark.parametrize("n", [2, 33, 128])
@pytest.mark.parametrize("which", ["stack", "stackless"])
def test_sphere_traversal_counts_match_bruteforce(n, which):
    pts = _rand(n, seed=7 * n)
    bvh = _build(pts)
    eps = 0.3
    eps2 = eps * eps
    jp = jnp.asarray(pts)

    def run(center):
        def fn(count, j, _s):
            hit = jnp.sum((jp[j] - center) ** 2) <= eps2
            return count + hit.astype(jnp.int32), jnp.bool_(False)
        trav = traverse_sphere_stack if which == "stack" else traverse_sphere_stackless
        return trav(bvh, center[None], eps, fn, jnp.int32(0))[0]

    import jax
    got = np.asarray(jax.vmap(run)(jp))
    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    want = (d2 <= eps2).sum(1)
    np.testing.assert_array_equal(got, want)


def test_early_termination_saturates():
    """§4.1.2: traversal must stop once the callback reports done."""
    import jax
    pts = _rand(100, seed=3)
    bvh = _build(pts)
    jp = jnp.asarray(pts)
    cap = 3

    def run(center):
        def fn(count, j, _s):
            hit = jnp.sum((jp[j] - center) ** 2) <= 1.0  # everything hits
            c = count + hit.astype(jnp.int32)
            return c, c >= cap
        return traverse_sphere_stackless(bvh, center[None], 2.0, fn, jnp.int32(0))[0]

    got = np.asarray(jax.vmap(run)(jp))
    assert (got == cap).all()


@given(st.integers(2, 80), st.floats(0.02, 0.6))
@settings(max_examples=25, deadline=None)
def test_pair_traversal_each_pair_exactly_once(n, eps):
    """Property (paper §4.2.3): pair traversal finds each ε-pair (i<j) exactly
    once, none missed, none duplicated."""
    pts = _rand(n, seed=n)
    bvh = _build(pts)
    jp = jnp.asarray(pts)
    eps2 = eps * eps
    cap = max(8, n)

    def fn(carry, i, j):
        buf, cnt = carry
        hit = jnp.sum((jp[j] - jp[i]) ** 2) <= eps2
        slot = jnp.clip(cnt, 0, cap - 1)
        buf = jnp.where(hit, buf.at[slot].set(j), buf)
        return (buf, cnt + hit.astype(jnp.int32)), jnp.bool_(False)

    buf0 = jnp.full((cap,), -1, jnp.int32)
    buf, cnt = pair_traverse_sphere(bvh, jp, eps, fn, (buf0, jnp.int32(0)))
    buf, cnt = np.asarray(buf), np.asarray(cnt)
    perm = np.asarray(bvh.leaf_perm)
    got = []
    for k in range(n):
        i = perm[k]
        for s in range(cnt[k]):
            a, b = min(i, buf[k, s]), max(i, buf[k, s])
            got.append((a, b))
    assert len(got) == len(set(got))  # exactly once

    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    want = {(i, j) for i in range(n) for j in range(i + 1, n) if d2[i, j] <= eps2}
    assert set(got) == want


def test_32bit_collapse_does_not_break_correctness():
    """Many identical Morton codes (degenerate clustered data) must still give
    a valid tree — the paper's motivation for index tie-breaking."""
    rng = np.random.default_rng(9)
    base = rng.uniform(0.4, 0.6, (1, 3))
    pts = (base + rng.normal(0, 1e-7, (300, 3))).astype(np.float32)  # 1 bin at 32-bit
    lo, hi = pts.min(0) - 0.1, pts.max(0) + 0.1
    bvh = build_bvh(jnp.asarray(pts), jnp.asarray(lo), jnp.asarray(hi), use_64bit=False)
    left = np.asarray(bvh.left_child)
    rope = np.asarray(bvh.rope)
    n = 300
    node, cnt = 0, 0
    while node != int(SENTINEL):
        if node >= n - 1:
            cnt += 1
            node = int(rope[node])
        else:
            node = int(left[node])
        assert cnt <= n
    assert cnt == n
