"""Attention unit tests: blockwise (flash) vs dense equivalence, GQA
broadcast, softcap, RoPE properties, custom-VJP sLSTM gradients."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.models.attention as A
import repro.models.ssm as S
from repro.configs import get_config
from repro.models.layers import rope
from repro.models.spec import init_params


@pytest.fixture(autouse=True)
def small_chunks(monkeypatch):
    monkeypatch.setattr(A, "Q_CHUNK", 16)
    monkeypatch.setattr(A, "KV_CHUNK", 16)


def _qkv(rng, b, s, h, kv, hd):
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window,causal", [(None, True), (24, True),
                                           (None, False)])
def test_chunked_attention_matches_dense(window, causal):
    cfg = get_config("gemma2-9b").smoke()
    q, k, v = _qkv(np.random.default_rng(0), 2, 64, 4, 4, 16)
    dense = A._sdpa(cfg, q, k, v,
                    A._causal_mask(64, window) if causal else None)
    chunked = A._sdpa_chunked(cfg, q, k, v, window=window, causal=causal)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5)


def test_chunked_attention_gqa():
    cfg = get_config("gemma2-9b").smoke()
    q, k, v = _qkv(np.random.default_rng(1), 2, 64, 8, 4, 16)  # rep=2
    dense = A._sdpa(cfg, q, k, v, A._causal_mask(64, None))
    chunked = A._sdpa_chunked(cfg, q, k, v, window=None, causal=True)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5)


def test_chunked_attention_gradients_match():
    cfg = get_config("gemma2-9b").smoke()
    q, k, v = _qkv(np.random.default_rng(2), 1, 32, 2, 2, 8)

    def f_dense(q):
        return jnp.sum(A._sdpa(cfg, q, k, v, A._causal_mask(32, None)) ** 2)

    def f_chunk(q):
        return jnp.sum(A._sdpa_chunked(cfg, q, k, v, window=None,
                                       causal=True) ** 2)

    g1 = jax.grad(f_dense)(q)
    g2 = jax.grad(f_chunk)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


def test_softcap_attention_applies_in_chunks():
    import dataclasses
    cfg = dataclasses.replace(get_config("gemma2-9b").smoke(),
                              attn_softcap=5.0)
    q, k, v = _qkv(np.random.default_rng(3), 1, 32, 2, 2, 8)
    dense = A._sdpa(cfg, q * 4, k, v, A._causal_mask(32, None))
    chunked = A._sdpa_chunked(cfg, q * 4, k, v, window=None, causal=True)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5)


def test_rope_relative_property():
    """RoPE: <rope(q, m), rope(k, n)> depends only on m - n."""
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)

    def dot_at(m, n):
        qm = rope(q, jnp.full((1, 1), m), 10000.0)
        kn = rope(k, jnp.full((1, 1), n), 10000.0)
        return float(jnp.sum(qm * kn))

    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)
    assert dot_at(7, 0) == pytest.approx(dot_at(57, 50), rel=1e-4)


def test_slstm_custom_vjp_matches_autodiff():
    """The hand-written sLSTM backward (EXPERIMENTS §Perf xlstm v2b) must be
    exact against plain autodiff of the same step function."""
    cfg = get_config("xlstm-350m").smoke()
    params = init_params({"s": S.slstm_spec(cfg)}, jax.random.PRNGKey(0),
                         jnp.float32)["s"]
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 12, cfg.d_model)), jnp.float32)

    def loss_custom(params, x):
        out, _ = S.slstm(params, cfg, x)
        return jnp.sum(out ** 2)

    def slstm_ref(p, h_in):
        b, s, d = h_in.shape
        dt = h_in.dtype
        pre = [jnp.einsum("bsd,dhk->sbhk", h_in, p[k_].astype(dt)).astype(jnp.float32)
               for k_ in ("wz", "wi", "wf", "wo")]
        r = p["r"].astype(dt)
        h0 = jnp.zeros((b, cfg.n_heads, cfg.resolved_head_dim), jnp.float32)
        (hf, cf, nf), ys = jax.lax.scan(
            lambda c, xx: S._slstm_step(r, c, xx), (h0, h0, h0 + 1.0),
            tuple(pre))
        y = ys.swapaxes(0, 1).astype(dt)
        return jnp.einsum("bshk,hkd->bsd", y, p["out"].astype(dt))

    def loss_ref(params, x):
        return jnp.sum(slstm_ref(params, x) ** 2)

    v1, g1 = jax.value_and_grad(loss_custom)(params, x)
    v2, g2 = jax.value_and_grad(loss_ref)(params, x)
    assert float(v1) == pytest.approx(float(v2), rel=1e-6)
    for kk in g1:
        np.testing.assert_allclose(np.asarray(g1[kk]), np.asarray(g2[kk]),
                                   atol=2e-5, err_msg=kk)
    gx1 = jax.grad(loss_custom, argnums=1)(params, x)
    gx2 = jax.grad(loss_ref, argnums=1)(params, x)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), atol=2e-5)
