"""2-point correlation pair counts (paper §4.2.3 use case)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.correlation import pair_count_histogram, two_point_correlation
from conftest import make_clustered_points


def _brute_hist(pts, r_max, n_bins):
    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    iu = np.triu_indices(len(pts), 1)
    d = np.sqrt(d2[iu])
    d = d[d <= r_max]
    hist, _ = np.histogram(d, bins=n_bins, range=(0, r_max))
    return hist


def test_pair_counts_match_bruteforce():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1, (150, 3)).astype(np.float32)
    r_max, n_bins = 0.3, 8
    got = np.asarray(pair_count_histogram(jnp.asarray(pts), r_max, n_bins))
    want = _brute_hist(pts, r_max, n_bins)
    # bin-edge float ties can move a pair by one bin; totals must agree
    assert got.sum() == want.sum()
    np.testing.assert_allclose(got, want, atol=2)


def test_clustered_data_has_positive_small_scale_xi():
    """Clustered (halo) data must show ξ(r) >> 0 at small r — the physical
    signal HACC measures."""
    rng = np.random.default_rng(1)
    pts = make_clustered_points(rng, 600)
    xi, dd, edges = two_point_correlation(jnp.asarray(pts), 0.2, 10)
    assert xi[0] > 1.0          # strong small-scale clustering
    assert abs(xi[-1]) < 2.0    # ~uniform at larger r


def test_uniform_data_has_flat_xi():
    rng = np.random.default_rng(2)
    pts = rng.uniform(0, 1, (800, 3)).astype(np.float32)
    xi, dd, edges = two_point_correlation(jnp.asarray(pts), 0.15, 6)
    # skip the first bin (few pairs, noisy); the rest should be ~0
    assert np.all(np.abs(xi[1:]) < 0.35), xi
