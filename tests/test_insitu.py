"""In-situ analysis (the paper's technique inside the training loop)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis.insitu import (InsituAnalyzer, InsituConfig,
                                   embedding_cluster_stats,
                                   router_cluster_stats)
from repro.configs import get_config
from repro.models import lm
from repro.models.spec import init_params


def _params(arch="xlstm-350m"):
    cfg = get_config(arch).smoke()
    return cfg, init_params(lm.model_spec(cfg), jax.random.PRNGKey(0),
                            jnp.float32)


def test_embedding_stats_fields_and_finiteness():
    cfg, params = _params()
    stats = embedding_cluster_stats(params, InsituConfig(sample_rows=128), 3)
    assert set(stats) >= {"insitu/embed_eps", "insitu/embed_clustered_frac",
                          "insitu/embed_num_clusters"}
    for v in stats.values():
        assert bool(jnp.all(jnp.isfinite(jnp.asarray(v, jnp.float32))))


def test_detects_representation_collapse():
    """Duplicate embedding rows (collapse) => clustered fraction jumps."""
    cfg, params = _params()
    icfg = InsituConfig(sample_rows=128, eps_quantile=0.005)
    base = embedding_cluster_stats(params, icfg, 1)
    collapsed = dict(params)
    emb = params["embed"]
    # collapse 80% of rows onto row 0
    n = emb.shape[0]
    idx = jnp.arange(n)
    collapsed["embed"] = jnp.where((idx % 5 > 0)[:, None], emb[0][None], emb)
    after = embedding_cluster_stats(collapsed, icfg, 1)
    assert float(after["insitu/embed_clustered_frac"]) > \
        float(base["insitu/embed_clustered_frac"])


def test_router_stats_on_moe_arch():
    cfg, params = _params("deepseek-moe-16b")
    stats = router_cluster_stats(params, InsituConfig(), 0)
    assert "insitu/router_collapsed_experts" in stats


def test_router_stats_empty_for_dense_arch():
    cfg, params = _params("granite-20b")
    assert router_cluster_stats(params, InsituConfig(), 0) == {}


def test_analyzer_cadence():
    cfg, params = _params()
    an = InsituAnalyzer(InsituConfig(cadence=5, sample_rows=64))
    ran = [step for step in range(11) if an.maybe_run(params, step)]
    assert ran == [0, 5, 10]
    assert len(an.history) == 3
