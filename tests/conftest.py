"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device. Only launch/dryrun.py
sets --xla_force_host_platform_device_count (in its own process)."""
from __future__ import annotations

import importlib.util
import os
import sys

import numpy as np
import pytest

# Optional dev dependency: the property tests import hypothesis at module
# scope, which used to crash the ENTIRE collection when it wasn't installed.
# Fall back to the deterministic shim (see _hypothesis_shim.py) so the suite
# always runs; install requirements-dev.txt for the real thing.
try:  # pragma: no cover - trivial import probe
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_shim.py"))
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies


def abstract_mesh(sizes, names):
    """AbstractMesh across JAX versions: <=0.4.x takes ((name, size), ...)
    pairs; newer releases take (sizes, names)."""
    import jax
    try:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(sizes), tuple(names))


def make_clustered_points(rng: np.random.Generator, n: int, d: int = 3,
                          n_halos: int = 4, noise_frac: float = 0.25) -> np.ndarray:
    """Clustered point set qualitatively matching the paper's benchmark data:
    dense NFW-like blobs (halos) + uniform background noise in [0, 1)^d."""
    n_noise = int(n * noise_frac)
    n_clustered = n - n_noise
    centers = rng.uniform(0.15, 0.85, (n_halos, d))
    sizes = rng.multinomial(n_clustered, np.ones(n_halos) / n_halos)
    parts = [rng.uniform(0.0, 1.0, (n_noise, d))]
    for c, s in zip(centers, sizes):
        # NFW-ish: radius ~ r0 * u^2 concentrates mass at the center.
        u = rng.uniform(0, 1, (s, 1)) ** 2
        direction = rng.normal(size=(s, d))
        direction /= np.maximum(np.linalg.norm(direction, axis=1, keepdims=True), 1e-9)
        parts.append(c + 0.08 * u * direction)
    pts = np.concatenate(parts).astype(np.float32)
    return np.clip(pts, 0.0, 1.0 - 1e-6)


@pytest.fixture
def rng():
    return np.random.default_rng(12345)


@pytest.fixture
def clustered_points(rng):
    return make_clustered_points(rng, 400)
