"""kNN, EMST, MLS interpolation, ray casting — the rest of ArborX's §3.2
functionality surface."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bvh import build_bvh, build_bvh_objects
from repro.core.emst import emst
from repro.core.interpolate import mls_interpolate
from repro.core.knn import knn
from repro.core.raycast import raycast


def _bvh(pts):
    lo, hi = pts.min(0) - 1e-4, pts.max(0) + 1e-4
    return build_bvh(jnp.asarray(pts), jnp.asarray(lo), jnp.asarray(hi))


# --- kNN ---------------------------------------------------------------------

@pytest.mark.parametrize("n,k,q", [(16, 1, 8), (128, 4, 32), (256, 15, 16)])
def test_knn_matches_bruteforce(n, k, q):
    rng = np.random.default_rng(n + k)
    pts = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    queries = rng.uniform(0, 1, (q, 3)).astype(np.float32)
    res = knn(_bvh(pts), jnp.asarray(pts), jnp.asarray(queries), k)
    d = np.sqrt(((queries[:, None] - pts[None]) ** 2).sum(-1))
    want = np.sort(d, axis=1)[:, :k]
    np.testing.assert_allclose(np.asarray(res.distances), want, atol=1e-5)


def test_knn_self_query_returns_self_first():
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 1, (64, 3)).astype(np.float32)
    res = knn(_bvh(pts), jnp.asarray(pts), jnp.asarray(pts), 3)
    np.testing.assert_array_equal(np.asarray(res.indices[:, 0]), np.arange(64))
    np.testing.assert_allclose(np.asarray(res.distances[:, 0]), 0, atol=1e-6)


@given(n=st.integers(4, 100), k=st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_knn_property(n, k):
    k = min(k, n)
    rng = np.random.default_rng(n * 31 + k)
    pts = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    queries = rng.uniform(0, 1, (5, 3)).astype(np.float32)
    res = knn(_bvh(pts), jnp.asarray(pts), jnp.asarray(queries), k)
    d = np.sqrt(((queries[:, None] - pts[None]) ** 2).sum(-1))
    want = np.sort(d, axis=1)[:, :k]
    np.testing.assert_allclose(np.asarray(res.distances), want, atol=1e-5)


# --- EMST ---------------------------------------------------------------------

def _prim_weight(pts):
    n = len(pts)
    d = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1))
    in_tree = np.zeros(n, bool)
    in_tree[0] = True
    best = d[0].copy()
    total = 0.0
    for _ in range(n - 1):
        best[in_tree] = np.inf
        j = np.argmin(best)
        total += best[j]
        in_tree[j] = True
        best = np.minimum(best, d[j])
    return total


@pytest.mark.parametrize("n", [8, 64, 300])
def test_emst_weight_matches_prim(n):
    rng = np.random.default_rng(n)
    pts = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    res = emst(jnp.asarray(pts))
    edges = np.asarray(res.edges)
    assert (edges >= 0).all()
    assert float(res.total_weight) == pytest.approx(_prim_weight(pts), rel=1e-5)


def test_emst_is_spanning_tree():
    rng = np.random.default_rng(7)
    pts = rng.uniform(0, 1, (100, 3)).astype(np.float32)
    res = emst(jnp.asarray(pts))
    edges = np.asarray(res.edges)
    # n-1 edges, connected, acyclic => union-find sanity
    parent = list(range(100))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in edges:
        ra, rb = find(a), find(b)
        assert ra != rb, "cycle in EMST"
        parent[ra] = rb
    assert len({find(i) for i in range(100)}) == 1, "not spanning"


@given(seed=st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_emst_property(seed):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (40, 3)).astype(np.float32)
    res = emst(jnp.asarray(pts))
    assert float(res.total_weight) == pytest.approx(_prim_weight(pts), rel=1e-5)


# --- MLS interpolation ---------------------------------------------------------

def test_mls_reproduces_linear_fields():
    """Degree-1 MLS must reproduce linear functions exactly (consistency)."""
    rng = np.random.default_rng(3)
    src = rng.uniform(0, 1, (400, 3)).astype(np.float32)
    tgt = rng.uniform(0.1, 0.9, (50, 3)).astype(np.float32)
    f = lambda p: 2.0 * p[:, 0] - 3.0 * p[:, 1] + 0.5 * p[:, 2] + 1.0
    got = np.asarray(mls_interpolate(jnp.asarray(src), jnp.asarray(f(src)),
                                     jnp.asarray(tgt), k=10))
    np.testing.assert_allclose(got, f(tgt), rtol=1e-3, atol=1e-3)


def test_mls_approximates_smooth_field():
    rng = np.random.default_rng(4)
    src = rng.uniform(0, 1, (2000, 3)).astype(np.float32)
    tgt = rng.uniform(0.2, 0.8, (40, 3)).astype(np.float32)
    f = lambda p: np.sin(2 * p[:, 0]) * np.cos(p[:, 1]) + p[:, 2] ** 2
    got = np.asarray(mls_interpolate(jnp.asarray(src), jnp.asarray(f(src).astype(np.float32)),
                                     jnp.asarray(tgt), k=12))
    err = np.abs(got - f(tgt))
    assert err.max() < 0.05, err.max()


# --- ray casting ----------------------------------------------------------------

def test_raycast_nearest_box():
    # three unit-ish boxes along +x; ray from origin must hit the nearest
    lo = np.array([[1, -.1, -.1], [3, -.1, -.1], [5, -.1, -.1]], np.float32)
    hi = lo + np.float32(0.5)
    scene_lo, scene_hi = lo.min(0) - 1, hi.max(0) + 1
    bvh = build_bvh_objects(jnp.asarray(lo), jnp.asarray(hi),
                            jnp.asarray(scene_lo), jnp.asarray(scene_hi))
    origins = np.zeros((2, 3), np.float32)
    dirs = np.array([[1, 0, 0], [-1, 0, 0]], np.float32)
    hits = raycast(bvh, jnp.asarray(origins), jnp.asarray(dirs))
    assert int(hits.index[0]) == 0 and float(hits.t[0]) == pytest.approx(1.0)
    assert int(hits.index[1]) == -1  # miss


def test_raycast_matches_bruteforce_random():
    rng = np.random.default_rng(5)
    n = 60
    lo = rng.uniform(0, 1, (n, 3)).astype(np.float32)
    hi = lo + rng.uniform(0.01, 0.08, (n, 3)).astype(np.float32)
    bvh = build_bvh_objects(jnp.asarray(lo), jnp.asarray(hi),
                            jnp.asarray(lo.min(0) - .1), jnp.asarray(hi.max(0) + .1))
    origins = rng.uniform(-0.5, 0, (20, 3)).astype(np.float32)
    dirs = rng.standard_normal((20, 3)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    hits = raycast(bvh, jnp.asarray(origins), jnp.asarray(dirs))

    def brute(o, d):
        inv = 1.0 / np.where(np.abs(d) < 1e-12, 1e-12, d)
        t0 = (lo - o) * inv
        t1 = (hi - o) * inv
        tmin = np.minimum(t0, t1).max(1)
        tmax = np.maximum(t0, t1).min(1)
        ok = tmax >= np.maximum(tmin, 0)
        te = np.where(ok, np.maximum(tmin, 0), np.inf)
        j = te.argmin()
        return (j, te[j]) if np.isfinite(te[j]) else (-1, np.inf)

    import pytest as _pt
    for r in range(20):
        j, t = brute(origins[r], dirs[r])
        assert int(hits.index[r]) == j, r
        if j >= 0:
            assert float(hits.t[r]) == _pt.approx(t, rel=1e-4)


import pytest  # noqa: E402  (used in raycast tests above)
