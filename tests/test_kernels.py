"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.pairwise import SENTINEL_LABEL
from repro.core.fdbscan_grid import bin_points, stencil_neighbor_map, grid_dims_for


def _pts(rng, n, d):
    return rng.uniform(0, 1, (n, d)).astype(np.float32)


@pytest.mark.parametrize("m,n", [(1, 1), (5, 7), (128, 128), (130, 257), (64, 300)])
@pytest.mark.parametrize("d", [1, 3, 8, 17, 64])
def test_pairwise_count_shapes(m, n, d):
    rng = np.random.default_rng(m * 1000 + n + d)
    x, y = _pts(rng, m, d), _pts(rng, n, d)
    eps = 0.5
    got = np.asarray(ops.eps_neighbor_counts(jnp.asarray(x), jnp.asarray(y), eps))
    want = np.asarray(ref.pairwise_count_ref(jnp.asarray(x), jnp.asarray(y), eps * eps))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("m,n", [(3, 3), (128, 128), (100, 260)])
@pytest.mark.parametrize("d", [2, 3, 16])
def test_pairwise_min_label_shapes(m, n, d):
    rng = np.random.default_rng(m + n * 31 + d)
    x, y = _pts(rng, m, d), _pts(rng, n, d)
    labels = rng.integers(0, n, n).astype(np.int32)
    core = rng.uniform(size=n) < 0.6
    eps = 0.4
    got = np.asarray(ops.eps_min_label(jnp.asarray(x), jnp.asarray(y),
                                       jnp.asarray(labels), jnp.asarray(core), eps))
    want = np.asarray(ref.pairwise_min_label_ref(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(labels), jnp.asarray(core), eps * eps))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("tile", [(32, 32), (8, 128)])
def test_pairwise_count_tile_shapes(tile):
    tm, tn = tile
    rng = np.random.default_rng(42)
    x, y = _pts(rng, 40, 3), _pts(rng, 70, 3)
    got = np.asarray(ops.eps_neighbor_counts(jnp.asarray(x), jnp.asarray(y), 0.3,
                                             tm=tm, tn=tn))
    want = np.asarray(ref.pairwise_count_ref(jnp.asarray(x), jnp.asarray(y), 0.09))
    np.testing.assert_array_equal(got, want)


@given(m=st.integers(1, 80), n=st.integers(1, 80), d=st.integers(1, 9),
       eps=st.floats(0.01, 1.5))
@settings(max_examples=20, deadline=None)
def test_property_pairwise_count(m, n, d, eps):
    rng = np.random.default_rng(m * 97 + n * 13 + d)
    x, y = _pts(rng, m, d), _pts(rng, n, d)
    got = np.asarray(ops.eps_neighbor_counts(jnp.asarray(x), jnp.asarray(y), eps,
                                             tm=32, tn=32))
    d2 = ((x[:, None] - y[None]) ** 2).sum(-1)
    want = (d2 <= eps * eps).sum(1)
    np.testing.assert_array_equal(got, want)


def test_count_self_includes_self():
    x = np.zeros((4, 3), np.float32)
    got = np.asarray(ops.eps_neighbor_counts(jnp.asarray(x), jnp.asarray(x), 0.1))
    np.testing.assert_array_equal(got, [4, 4, 4, 4])


@pytest.mark.parametrize("capacity", [4, 16])
def test_stencil_count_matches_ref(capacity):
    rng = np.random.default_rng(0)
    pts = _pts(rng, 150, 3)
    eps = 0.2
    dims = grid_dims_for(np.zeros(3), np.ones(3), eps)
    bins = bin_points(jnp.asarray(pts), jnp.zeros(3, jnp.float32), eps, dims, capacity)
    nbr = jnp.asarray(stencil_neighbor_map(dims))
    got = np.asarray(ops.cell_stencil_counts(bins.cell_pts, nbr, eps))
    want = np.asarray(ref.stencil_count_ref(bins.cell_pts, nbr, eps * eps))
    np.testing.assert_array_equal(got, want)


def test_stencil_min_label_matches_ref():
    rng = np.random.default_rng(1)
    pts = _pts(rng, 120, 3)
    eps = 0.25
    cap = 16
    dims = grid_dims_for(np.zeros(3), np.ones(3), eps)
    bins = bin_points(jnp.asarray(pts), jnp.zeros(3, jnp.float32), eps, dims, cap)
    ncells = bins.num_cells
    nbr = jnp.asarray(stencil_neighbor_map(dims))
    lab = jnp.asarray(rng.integers(0, 120, (ncells + 1, cap)), jnp.int32)
    core = jnp.asarray(rng.uniform(size=(ncells + 1, cap)) < 0.7)
    got = np.asarray(ops.cell_stencil_min_label(bins.cell_pts, lab, core, nbr, eps))
    want = np.asarray(ref.stencil_min_label_ref(bins.cell_pts, lab, core, nbr, eps * eps))
    np.testing.assert_array_equal(got, want)


def test_stencil_counts_equal_bruteforce_per_point():
    """End-to-end: counts read back per point equal brute-force ε-counts."""
    rng = np.random.default_rng(5)
    pts = _pts(rng, 200, 3)
    eps = 0.15
    cap = 64
    dims = grid_dims_for(np.zeros(3), np.ones(3), eps)
    bins = bin_points(jnp.asarray(pts), jnp.zeros(3, jnp.float32), eps, dims, cap)
    assert not bool(bins.overflowed)
    nbr = jnp.asarray(stencil_neighbor_map(dims))
    counts_cells = np.asarray(ops.cell_stencil_counts(bins.cell_pts, nbr, eps))
    flat = np.concatenate([counts_cells.reshape(-1), np.zeros(cap, np.int32)])
    got = flat[np.asarray(bins.slot_of_point)]
    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    want = (d2 <= eps * eps).sum(1)
    np.testing.assert_array_equal(got, want)


def test_min_label_none_is_sentinel():
    x = np.zeros((2, 3), np.float32)
    y = np.ones((3, 3), np.float32)  # all out of eps range
    got = np.asarray(ops.eps_min_label(jnp.asarray(x), jnp.asarray(y),
                                       jnp.zeros(3, jnp.int32), jnp.ones(3, bool), 0.1))
    assert (got == SENTINEL_LABEL).all()
