"""End-to-end integration: the training driver trains (loss drops), survives
an injected failure, and the serving driver generates tokens."""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch import steps
from repro.models import lm
from repro.models.spec import init_params
from repro.optim import adamw
from repro.runtime.supervisor import Supervisor, SupervisorConfig


@pytest.mark.parametrize("arch", ["xlstm-350m", "deepseek-moe-16b"])
def test_training_reduces_loss(arch):
    cfg = get_config(arch).smoke()
    opt_cfg = adamw.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                              moment_dtype="float32")
    params = init_params(lm.model_spec(cfg), jax.random.PRNGKey(0), jnp.float32)
    state = steps.TrainState(params, adamw.init_opt_state(opt_cfg, params))
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=8, seed=1))
    jit_step = jax.jit(functools.partial(steps.train_step, cfg=cfg,
                                         opt_cfg=opt_cfg))
    losses = []
    for step in range(60):
        state, metrics = jit_step(state, data.batch_at(step))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2, \
        (np.mean(losses[:10]), np.mean(losses[-10:]))


def test_supervised_training_with_failure_and_restore(tmp_path):
    """Full loop: supervisor + checkpoint + injected crash; the final state
    must equal an uninterrupted run (exact resume)."""
    cfg = get_config("xlstm-350m").smoke()
    opt_cfg = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=30,
                              moment_dtype="float32")
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=16,
                                      global_batch=4, seed=2))
    jit_step = jax.jit(functools.partial(steps.train_step, cfg=cfg,
                                         opt_cfg=opt_cfg))

    def init_state():
        params = init_params(lm.model_spec(cfg), jax.random.PRNGKey(3),
                             jnp.float32)
        return steps.TrainState(params, adamw.init_opt_state(opt_cfg, params))

    def step_fn(state, step):
        return jit_step(state, data.batch_at(step))

    # uninterrupted reference
    ref_state = init_state()
    for s in range(30):
        ref_state, _ = step_fn(ref_state, s)

    crashed = {"done": False}

    def fault(step):
        if step == 17 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("node died")

    sup = Supervisor(SupervisorConfig(total_steps=30, checkpoint_every=10,
                                      max_restarts=2),
                     CheckpointStore(tmp_path))
    state = sup.run(init_state_fn=init_state, step_fn=step_fn, fault_hook=fault)
    assert sup.restarts == 1
    # exact determinism: resumed run == uninterrupted run
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(ref_state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0,
                                   err_msg="resume diverged from reference")


def test_serve_generates(tmp_path):
    cfg = get_config("gemma2-9b").smoke()
    params = init_params(lm.model_spec(cfg), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    b, s, gen = 2, 16, 6
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    prefill = jax.jit(functools.partial(steps.prefill_step, cfg=cfg,
                                        cache_len=s + gen))
    decode = jax.jit(functools.partial(steps.serve_step, cfg=cfg))
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    outs = [tok]
    for i in range(gen - 1):
        tok, _, cache = decode(params, cache, tok, jnp.int32(s + i))
        outs.append(tok)
    gen_arr = np.concatenate([np.asarray(t) for t in outs], axis=1)
    assert gen_arr.shape == (b, gen)
    assert (gen_arr >= 0).all() and (gen_arr < cfg.vocab).all()


def test_grad_accum_matches_single_step():
    """train_step_accum(2 micros) == train_step on the concatenated batch."""
    cfg = get_config("xlstm-350m").smoke()
    opt_cfg1 = adamw.OptConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                               moment_dtype="float32", accum_steps=1)
    opt_cfg2 = adamw.OptConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                               moment_dtype="float32", accum_steps=2)
    params = init_params(lm.model_spec(cfg), jax.random.PRNGKey(1), jnp.float32)
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=16,
                                      global_batch=4, seed=5))
    big = data.batch_at(0)
    state1 = steps.TrainState(params, adamw.init_opt_state(opt_cfg1, params))
    s1, m1 = steps.train_step(state1, big, cfg=cfg, opt_cfg=opt_cfg1)

    micro = jax.tree.map(lambda x: x.reshape((2, 2) + x.shape[1:]), big)
    state2 = steps.TrainState(params, adamw.init_opt_state(opt_cfg2, params))
    s2, m2 = steps.train_step_accum(state2, micro, cfg=cfg, opt_cfg=opt_cfg2)
    # same data => nearly identical updated params (accum averages losses)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=1e-2)
