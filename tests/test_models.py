"""Per-architecture smoke tests (deliverable (f)): reduced same-family
configs, one forward/train step on CPU, asserting output shapes + no NaNs;
plus prefill/decode vs full-forward consistency."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, shapes_for
from repro.models import lm
from repro.models.spec import count_params, init_params


def _batch(cfg, b, s, rng, with_labels=True):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
        batch["loss_mask"] = jnp.ones((b, s), bool)
    if cfg.frontend_dim and not cfg.encoder_layers:
        batch["vision"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).smoke()
            params = init_params(lm.model_spec(cfg), jax.random.PRNGKey(0),
                                 jnp.float32)
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, arch_state):
    cfg, params = arch_state(arch)
    rng = np.random.default_rng(1)
    batch = _batch(cfg, 2, 16, rng)
    loss, metrics = lm.train_loss(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert float(loss) < 3 * np.log(cfg.vocab) + 5
    assert bool(jnp.isfinite(metrics["aux_loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_gradients_finite(arch, arch_state):
    cfg, params = arch_state(arch)
    rng = np.random.default_rng(2)
    batch = _batch(cfg, 2, 16, rng)
    grads = jax.grad(lambda p: lm.train_loss(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), f"{arch}: NaN grad"
    # at least 90% of leaves get nonzero gradient signal
    nonzero = sum(bool(jnp.any(g != 0)) for g in flat)
    assert nonzero / len(flat) > 0.6, f"{arch}: too many dead grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch, arch_state):
    cfg, params = arch_state(arch)
    rng = np.random.default_rng(3)
    b, s = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 2)), jnp.int32)
    batch_full = _batch(cfg, b, s, rng, with_labels=False)
    batch_full["tokens"] = toks
    logits_full, _ = lm.prefill(params, cfg, batch_full)

    batch = dict(batch_full, tokens=toks[:, :s])
    _, cache = lm.prefill(params, cfg, batch, cache_len=s + 2)
    lg, cache = lm.decode_step(params, cfg, toks[:, s:s + 1], cache, jnp.int32(s))
    lg, cache = lm.decode_step(params, cfg, toks[:, s + 1:s + 2], cache,
                               jnp.int32(s + 1))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full),
                               atol=2e-3, rtol=1e-3,
                               err_msg=f"{arch}: decode != full forward")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_matches_nominal(arch):
    """Config sanity: full (non-smoke) spec matches the published size."""
    nominal = {
        "gemma2-9b": 9.2e9, "phi3-medium-14b": 14.7e9,
        "codeqwen1.5-7b": 8.2e9, "granite-20b": 20.0e9,
        "deepseek-moe-16b": 16.4e9, "qwen3-moe-235b-a22b": 235e9,
        "llama-3.2-vision-11b": 9.8e9,  # minus the stubbed vision tower
        "seamless-m4t-large-v2": 1.7e9,  # minus the stubbed speech frontend
        "xlstm-350m": 0.34e9, "jamba-1.5-large-398b": 398e9,
    }[arch]
    n = count_params(lm.model_spec(get_config(arch)))
    assert abs(n - nominal) / nominal < 0.05, (arch, n, nominal)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shape_assignment_rules(arch):
    cfg = get_config(arch)
    names = {s.name for s in shapes_for(cfg)}
    assert {"train_4k", "prefill_32k"} <= names
    if cfg.family in ("ssm", "hybrid"):
        assert "long_500k" in names
    else:
        assert "long_500k" not in names


def test_gemma2_sliding_window_masks_long_range(arch_state):
    """Local-attention layers must not see past their RECEPTIVE FIELD —
    n_layers * (window - 1) positions (information propagates one window
    per layer). Perturbing a token beyond that must not change the last
    position's logits; perturbing one inside it must."""
    import dataclasses
    cfg, _ = arch_state("gemma2-9b")
    cfg_local = dataclasses.replace(
        cfg, n_layers=2, block_pattern=("attn_local", "attn_local"))
    params_local = init_params(lm.model_spec(cfg_local), jax.random.PRNGKey(0),
                               jnp.float32)
    rng = np.random.default_rng(5)
    w = cfg_local.sliding_window  # 16 in smoke
    s = 4 * w                     # 64; receptive field of pos 63 = 2*(w-1)=30
    toks = jnp.asarray(rng.integers(0, cfg_local.vocab, (1, s)), jnp.int32)
    l1, _ = lm.prefill(params_local, cfg_local, {"tokens": toks})
    # outside the receptive field: no effect
    toks_far = toks.at[0, 0].set((toks[0, 0] + 1) % cfg_local.vocab)
    l2, _ = lm.prefill(params_local, cfg_local, {"tokens": toks_far})
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-5)
    # inside the window: must change
    toks_near = toks.at[0, s - 2].set((toks[0, s - 2] + 1) % cfg_local.vocab)
    l3, _ = lm.prefill(params_local, cfg_local, {"tokens": toks_near})
    assert float(jnp.max(jnp.abs(l3[:, -1] - l1[:, -1]))) > 1e-6


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor, some tokens must be dropped (output
    differs from the no-drop setting) — the MoE dispatch is real."""
    import dataclasses
    cfg = get_config("deepseek-moe-16b").smoke()
    cfg_drop = dataclasses.replace(cfg, capacity_factor=0.1)
    params = init_params(lm.model_spec(cfg), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(6)
    batch = _batch(cfg, 2, 16, rng)
    l1, _ = lm.train_loss(params, cfg, batch)
    l2, _ = lm.train_loss(params, cfg_drop, batch)
    assert float(l1) != float(l2)
