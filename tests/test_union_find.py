"""Union-find / connected-components tests (paper §4.3, deviation 3)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import union_find


def _ref_components(n, edges):
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in edges:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(i) for i in range(n)])


@given(st.integers(1, 60), st.lists(st.tuples(st.integers(0, 59), st.integers(0, 59)), max_size=120))
@settings(max_examples=60, deadline=None)
def test_connected_components_matches_reference(n, raw_edges):
    edges = [(u % n, v % n) for u, v in raw_edges]
    if edges:
        u = jnp.asarray([e[0] for e in edges], jnp.int32)
        v = jnp.asarray([e[1] for e in edges], jnp.int32)
    else:
        u = v = jnp.zeros((1,), jnp.int32)
        edges = [(0, 0)]
    got = np.asarray(union_find.connected_components(n, u, v))
    want = _ref_components(n, edges)
    np.testing.assert_array_equal(got, want)


def test_compress_idempotent():
    p = jnp.asarray([0, 0, 1, 2, 3, 5, 5], jnp.int32)
    c = union_find.compress(p)
    np.testing.assert_array_equal(np.asarray(c), [0, 0, 0, 0, 0, 5, 5])
    np.testing.assert_array_equal(np.asarray(union_find.compress(c)), np.asarray(c))


def test_hook_min_is_deterministic_under_duplicate_edges():
    p = jnp.arange(6, dtype=jnp.int32)
    u = jnp.asarray([0, 0, 5, 5], jnp.int32)
    v = jnp.asarray([5, 5, 0, 0], jnp.int32)
    m = jnp.ones(4, bool)
    p1 = union_find.hook_min(p, u, v, m)
    p2 = union_find.hook_min(p, u, v, m)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    assert int(p1[5]) == 0


def test_labels_are_min_index_of_component():
    # chain 3-4-5 and pair (0,2); 1 isolated
    u = jnp.asarray([3, 4, 0], jnp.int32)
    v = jnp.asarray([4, 5, 2], jnp.int32)
    got = np.asarray(union_find.connected_components(6, u, v))
    np.testing.assert_array_equal(got, [0, 1, 0, 3, 3, 3])
