"""The scale-safety analyzer audits itself: every W rule must fire
exactly on its seeded broken fixture and stay silent on the fixed twin;
the lattice transfer functions must be SOUND (brute-force containment
over enumerated concrete inputs); every registered production
configuration must analyze clean at symbolic N = 1e9; and the runtime
behavior the analyzer proves (int64 CSR offsets past 2^31, int64 halo
labels, clamped Morton quantization) is regression-tested at
mocked-large sizes.
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.staticcheck.absint import (SymbolicScale, analyze, audit_routes,
                                      scale_for, CollectiveUse)
from repro.staticcheck.absint_registry import (REGISTERED_ABSINT_AUDITS,
                                               SEEDED_FIXTURES)
from repro.staticcheck.lattice import Ival
from repro.staticcheck import lattice as lat

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_SYM = 10**9


def _scale(**kw):
    return SymbolicScale(dims=scale_for(254, N_SYM), **kw)


# --- lattice soundness: brute-force containment ------------------------------

_INTS = [Ival(-6, -2), Ival(-3, 3), Ival(0, 5), Ival(2, 7), Ival(4, 4)]


def _enum(iv):
    return np.arange(int(iv.lo), int(iv.hi) + 1, dtype=np.int64)


@pytest.mark.parametrize("op,ref", [
    ("add", lambda x, y: x + y),
    ("sub", lambda x, y: x - y),
    ("mul", lambda x, y: x * y),
    ("imin", np.minimum),
    ("imax", np.maximum),
])
def test_lattice_binary_ops_contain_all_concrete_results(op, ref):
    f = getattr(lat, op)
    for a in _INTS:
        for b in _INTS:
            out = f(a, b)
            xs, ys = np.meshgrid(_enum(a), _enum(b))
            got = ref(xs, ys)
            assert out.known
            assert out.lo <= got.min() and got.max() <= out.hi, \
                (op, a, b, out, got.min(), got.max())


def test_lattice_division_and_remainder_sound_for_truncating_semantics():
    # jax.lax.div/rem truncate toward zero (C semantics)
    for a in _INTS:
        for b in _INTS:
            xs, ys = np.meshgrid(_enum(a), _enum(b))
            nz = ys != 0
            if not nz.any():
                continue
            q = np.trunc(xs[nz] / ys[nz])
            r = xs[nz] - q * ys[nz]
            # integer div is lat.div composed with truncate (what the
            # interpreter stages for int outputs)
            dq, dr = lat.truncate(lat.div(a, b)), lat.rem(a, b)
            assert dq.lo <= q.min() and q.max() <= dq.hi, (a, b, dq)
            assert dr.lo <= r.min() and r.max() <= dr.hi, (a, b, dr)


def test_lattice_bitwise_and_shifts_sound():
    small = [Ival(0, 7), Ival(2, 11), Ival(5, 5)]
    for a in small:
        for b in small:
            xs, ys = np.meshgrid(_enum(a), _enum(b))
            for op, ref in (("bit_and", np.bitwise_and),
                            ("bit_or", np.bitwise_or),
                            ("bit_xor", np.bitwise_xor)):
                out = getattr(lat, op)(a, b)
                got = ref(xs, ys)
                assert out.lo <= got.min() and got.max() <= out.hi, (op, a, b)
        for sh in (Ival(0, 3), Ival(1, 1)):
            xs, ys = np.meshgrid(_enum(a), _enum(sh))
            out = lat.shift_left(a, sh)
            got = xs << ys
            assert out.lo <= got.min() and got.max() <= out.hi, (a, sh, out)
            out = lat.shift_right(a, sh, arithmetic=True)
            got = xs >> ys
            assert out.lo <= got.min() and got.max() <= out.hi, (a, sh, out)


def test_lattice_unary_and_float_quantizers_sound():
    for a in _INTS:
        xs = _enum(a)
        for op, ref in (("neg", np.negative), ("iabs", np.abs)):
            out = getattr(lat, op)(a)
            got = ref(xs)
            assert out.lo <= got.min() and got.max() <= out.hi, (op, a)
    floats = [Ival(-2.75, 3.25), Ival(0.1, 0.9), Ival(-5.5, -1.5)]
    for a in floats:
        xs = np.linspace(a.lo, a.hi, 37)
        for op, ref in (("floor_op", np.floor), ("ceil_op", np.ceil),
                        ("round_op", np.round), ("truncate", np.trunc)):
            out = getattr(lat, op)(a)
            got = ref(xs)
            assert out.lo <= got.min() and got.max() <= out.hi, (op, a)


def test_lattice_join_meet_wrap():
    a, b = Ival(0, 5), Ival(3, 9)
    assert lat.join(a, b) == Ival(0, 9, True)
    assert lat.meet(a, b) == Ival(3, 5, True)
    assert lat.meet(Ival(0, 2), Ival(5, 9)) is None
    # uint32 wrap: an interval spanning the modulus degrades to full range
    w = lat.wrap_unsigned(Ival(-1, 1), jnp.dtype(jnp.uint32))
    assert w.lo == 0 and w.hi == 2**32 - 1


# --- seeded fixtures: each W rule fires, and only where seeded ---------------

@pytest.mark.parametrize("audit", SEEDED_FIXTURES, ids=lambda a: a.name)
def test_seeded_fixture_fires_its_rule(audit):
    rep = audit.run(True)
    fired = sorted({f.rule for f in rep.findings})
    assert fired == sorted(set(audit.expect_rules)), \
        [str(f) for f in rep.findings]
    if "W3-routes" not in audit.expect_rules:
        # value-level rules localize to ONE eqn; route tables may trip
        # several invariants at once
        assert len(rep.findings) == 1, [str(f) for f in rep.findings]


def test_fixed_twin_min_image_is_silent():
    L = 100.0

    def min_image_fixed(dx):
        dxc = jnp.clip(dx, -L, L)
        return dxc - jnp.round(dxc / L) * L

    rep = analyze(min_image_fixed, (jnp.zeros((254,), jnp.float32),),
                  name="minimg_fixed", scale=_scale(),
                  input_ivals=[Ival(-1.0e15, 1.0e15)])
    assert rep.findings == []


def test_fixed_twin_clipped_gather_is_silent():
    lab = jnp.zeros((254,), jnp.int32)
    idx = jnp.zeros((254,), jnp.int32)
    rep = analyze(lambda l, i: l[jnp.clip(i, 0, 253)], (lab, idx),
                  name="gather_fixed", scale=_scale(),
                  input_ivals=[Ival(0, 100), Ival(0, N_SYM)])
    assert rep.findings == []


def test_fixed_twin_f64_subtraction_meets_precision_floor():
    with jax.experimental.enable_x64():
        a = jnp.zeros((254,), jnp.float64)
        rep = analyze(lambda x, y: x - y, (a, a), name="cancel_f64",
                      scale=_scale(precision_floor=1e-3),
                      input_ivals=[Ival(1.0e9, 1.1e9), Ival(1.0e9, 1.1e9)])
    assert rep.findings == []


# --- analyzer mechanics ------------------------------------------------------

def test_scan_linear_widening_catches_accumulator_overflow():
    def acc(x):
        def body(c, xi):
            return c + xi, xi
        out, _ = jax.lax.scan(body, jnp.int32(0), x)
        return out

    rep = analyze(acc, (jnp.ones((254,), jnp.int32),), name="scan_acc",
                  scale=_scale(), input_ivals=[Ival(0, 2048)])
    assert [f.rule for f in rep.findings] == ["W1-index-width"]


def test_negative_index_canonicalization_not_flagged():
    # x[i] for i in [-N, N-1] stages lt/add/select_n; guard refinement must
    # keep both branches in [0, N-1]
    x = jnp.zeros((254,), jnp.float32)
    i = jnp.zeros((254,), jnp.int32)
    rep = analyze(lambda a, j: a[j], (x, i), name="neg_idx", scale=_scale(),
                  input_ivals=[None, Ival(-N_SYM, N_SYM - 1)])
    assert rep.findings == []


def test_cross_pjit_where_refinement():
    # jnp.where stages a pjit: the select_n sits one jaxpr below the
    # comparison producing its predicate. The sentinel-guarded index must
    # still refine to in-bounds.
    lab = jnp.zeros((254,), jnp.int32)
    i = jnp.zeros((254,), jnp.int32)

    def f(l, j):
        jj = jnp.where(j < l.shape[0], j, 0)
        return l[jj]

    rep = analyze(f, (lab, i), name="where_refine", scale=_scale(),
                  input_ivals=[Ival(0, 100), Ival(0, N_SYM)])
    assert rep.findings == []


def test_unsigned_wraparound_is_legal():
    # Morton-style magic-number multiply overflows uint32 by design
    def magic(v):
        v = v.astype(jnp.uint32) & jnp.uint32(0x3FF)
        return (v * jnp.uint32(0x00010001)) & jnp.uint32(0xFF0000FF)

    rep = analyze(magic, (jnp.zeros((254,), jnp.int32),), name="magic",
                  scale=_scale(), input_ivals=[Ival(0, 1023)])
    assert rep.findings == []


def test_symbolic_scale_reads_markers():
    sc = SymbolicScale(dims=scale_for(254, N_SYM))
    assert sc.dim(254) == N_SYM and sc.dim(253) == N_SYM - 1
    assert sc.dim(507) == 2 * N_SYM - 1 and sc.dim(17) == 17
    assert sc.lit(254) == N_SYM and sc.lit(True) is True
    assert sc.axis_size("data", 1) == 1
    assert SymbolicScale(axes={"data": 64}).axis_size("data", 1) == 64


def test_audit_routes_unit():
    mesh = {"data": 4}
    good = CollectiveUse("ppermute", ("data",),
                         ((0, 1), (1, 2), (2, 3), (3, 0)), mesh)
    assert audit_routes([good], "t") == []
    dup_dst = CollectiveUse("ppermute", ("data",), ((0, 1), (2, 1)), mesh)
    oob = CollectiveUse("ppermute", ("data",), ((0, 7),), mesh)
    bad_axis = CollectiveUse("psum", ("model",), (), mesh)
    msgs = [f.message for f in audit_routes([dup_dst, oob, bad_axis], "t")]
    assert any("duplicate destination" in m for m in msgs)
    assert any("outside the mesh axis" in m for m in msgs)
    assert any("not an axis of the enclosing mesh" in m for m in msgs)


# --- registered production configurations analyze clean ----------------------

@pytest.mark.parametrize("audit", REGISTERED_ABSINT_AUDITS,
                         ids=lambda a: a.name)
def test_registered_absint_audit_clean(audit):
    rep = audit.run(False)
    assert rep.findings == [], [str(f) for f in rep.findings]
    assert rep.values_analyzed > 0
    assert rep.unknown_prims == 0, \
        f"{rep.name}: {rep.unknown_prims} unmodelled primitives"


# --- the proved behavior, executed: index-width regression tests -------------

def test_csr_offsets_int64_past_2_31_at_mocked_large_counts():
    from repro.core.bvh import build_bvh
    from repro.core.geometry import scene_bounds
    from repro.core.query import query_csr_device, within

    with jax.experimental.enable_x64():
        pts = jnp.asarray(np.random.default_rng(0).random((4, 3)),
                          jnp.float32)
        lo, hi = scene_bounds(pts)
        bvh = build_bvh(pts, lo, hi)
        counts = jnp.full((4,), 2**30, jnp.int64)  # 4 * 2^30 = 2^32 hits
        csr = query_csr_device(bvh, within(pts, 0.1), 8, counts=counts,
                               index_dtype=jnp.int64)
        assert csr.offsets.dtype == jnp.dtype(jnp.int64)
        assert int(csr.offsets[-1]) == 2**32      # int32 would wrap to 0
        assert int(csr.total) == 2**32
        assert bool(csr.overflowed)


def test_csr_int64_requires_x64():
    from repro.core.query import _canon_index_dtype

    if jax.config.jax_enable_x64:
        pytest.skip("x64 globally enabled")
    with pytest.raises(ValueError, match="x64"):
        _canon_index_dtype(jnp.int64)
    assert _canon_index_dtype(jnp.int32) == jnp.dtype(jnp.int32)
    with pytest.raises(ValueError, match="int32 or int64"):
        _canon_index_dtype(jnp.float32)


def test_halo_catalog_labels_follow_int64_dtype():
    from repro.halos.catalog import canonicalize_labels, _sort_last

    with jax.experimental.enable_x64():
        # global ids beyond 2^31: the int32 sort sentinel (2^31-1) would
        # sort REAL labels after noise
        big = 2**31 + 5
        labels = jnp.asarray([big, -1, big, 7], jnp.int64)
        perm, pid_s, lab_s, member_s, nprov, _ = \
            canonicalize_labels(labels, capacity=4)
        assert lab_s.dtype == jnp.dtype(jnp.int64)
        assert int(_sort_last(jnp.int64)) == 2**63 - 1
        # noise sorts last, both big-label particles share a dense id
        assert not bool(member_s[-1])
        assert int(lab_s[0]) == 7 and int(lab_s[1]) == big
        assert int(pid_s[1]) == int(pid_s[2]) == 1
        assert int(nprov) == 2


def test_morton_quantize_clamps_before_cast():
    from repro.core.morton import _quantize, morton64

    big = jnp.asarray([[1.0e15, -1.0e15, 0.5]], jnp.float32)
    q = _quantize(big, 1 << 21)
    assert q.dtype == jnp.dtype(jnp.uint32)
    assert int(q[0, 0]) == (1 << 21) - 1 and int(q[0, 1]) == 0
    hi, lo = morton64(big)  # must not overflow the cast
    assert hi.dtype == lo.dtype == jnp.dtype(jnp.uint32)


# --- CLI contract ------------------------------------------------------------

def test_cli_absint_clean_tree_exits_zero(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    report = tmp_path / "sc.json"
    absint_report = tmp_path / "absint.json"
    out = subprocess.run(
        [sys.executable, "-m", "repro.staticcheck",
         os.path.join(REPO, "src", "repro"), "--absint", "--fast",
         "--json", str(report), "--absint-json", str(absint_report)],
        capture_output=True, text=True, env=env, cwd=str(tmp_path))
    assert out.returncode == 0, out.stdout + out.stderr
    data = json.loads(absint_report.read_text())
    assert data["ok"]
    names = [e["name"] for e in data["entrypoints"]]
    assert "query_csr_device[int64]" in names and "fdbscan" in names
    assert all(e["findings"] == [] for e in data["entrypoints"])
    assert sum(e["values_analyzed"] for e in data["entrypoints"]) > 1000
