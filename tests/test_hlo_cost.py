"""HLO cost-walker unit tests: trip-count multiplication, dot FLOPs,
collective accounting on small hand-checkable programs."""
from __future__ import annotations

import textwrap

import pytest

from repro.launch.hlo_cost import HloModule, analyze_hlo


SIMPLE = textwrap.dedent("""
    HloModule test

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %out = (s32[], f32[8,16]{1,0}) tuple(%i2, %y)
    }

    %cond (p2: (s32[], f32[8,16])) -> pred[] {
      %p2 = (s32[], f32[8,16]{1,0}) parameter(0)
      %i3 = s32[] get-tuple-element(%p2), index=0
      %lim = s32[] constant(7)
      ROOT %lt = pred[] compare(%i3, %lim), direction=LT
    }

    ENTRY %main (x0: f32[8,16]) -> f32[8,16] {
      %x0 = f32[8,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %x0)
      %w2 = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body
      ROOT %res = f32[8,16]{1,0} get-tuple-element(%w2), index=1
    }
""")


def test_while_trip_count_multiplies_dot_flops():
    res = analyze_hlo(SIMPLE)
    # one dot = 2*8*16*16 = 4096 flops, x7 trips
    assert res["flops"] == 7 * 2 * 8 * 16 * 16


def test_trip_count_parse():
    mod = HloModule(SIMPLE)
    assert mod.trip_count("cond") == 7


COLL = textwrap.dedent("""
    HloModule test2

    ENTRY %main (x: bf16[64,32]) -> bf16[64,32] {
      %x = bf16[64,32]{1,0} parameter(0)
      %ag = bf16[64,128]{1,0} all-gather(%x), replica_groups=[2,4]<=[8], dimensions={1}
      %ar = bf16[64,32]{1,0} all-reduce(%x), replica_groups=[8]<=[8], to_apply=%add
      ROOT %out = bf16[64,32]{1,0} add(%ar, %x)
    }
""")


def test_collective_bytes_true_dtype():
    res = analyze_hlo(COLL)
    ag = 64 * 128 * 2
    ar = 64 * 32 * 2 * 2  # all-reduce counted 2x
    assert res["coll"]["all-gather"] == ag
    assert res["coll"]["all-reduce"] == ar
    assert res["coll"]["total"] == ag + ar


def test_nested_while():
    nested = textwrap.dedent("""
        HloModule nested

        %inner_body (a: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
          %a = (s32[], f32[4,4]{1,0}) parameter(0)
          %ai = s32[] get-tuple-element(%a), index=0
          %ax = f32[4,4]{1,0} get-tuple-element(%a), index=1
          %m = f32[4,4]{1,0} dot(%ax, %ax), lhs_contracting_dims={1}, rhs_contracting_dims={0}
          %c1 = s32[] constant(1)
          %ai2 = s32[] add(%ai, %c1)
          ROOT %at = (s32[], f32[4,4]{1,0}) tuple(%ai2, %m)
        }

        %inner_cond (b: (s32[], f32[4,4])) -> pred[] {
          %b = (s32[], f32[4,4]{1,0}) parameter(0)
          %bi = s32[] get-tuple-element(%b), index=0
          %bl = s32[] constant(3)
          ROOT %bc = pred[] compare(%bi, %bl), direction=LT
        }

        %outer_body (c: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
          %c = (s32[], f32[4,4]{1,0}) parameter(0)
          %ci = s32[] get-tuple-element(%c), index=0
          %cx = f32[4,4]{1,0} get-tuple-element(%c), index=1
          %z = s32[] constant(0)
          %ini = (s32[], f32[4,4]{1,0}) tuple(%z, %cx)
          %iw = (s32[], f32[4,4]{1,0}) while(%ini), condition=%inner_cond, body=%inner_body
          %cy = f32[4,4]{1,0} get-tuple-element(%iw), index=1
          %c2 = s32[] constant(1)
          %ci2 = s32[] add(%ci, %c2)
          ROOT %ct = (s32[], f32[4,4]{1,0}) tuple(%ci2, %cy)
        }

        %outer_cond (d: (s32[], f32[4,4])) -> pred[] {
          %d = (s32[], f32[4,4]{1,0}) parameter(0)
          %di = s32[] get-tuple-element(%d), index=0
          %dl = s32[] constant(5)
          ROOT %dc = pred[] compare(%di, %dl), direction=LT
        }

        ENTRY %main (e: f32[4,4]) -> f32[4,4] {
          %e = f32[4,4]{1,0} parameter(0)
          %z2 = s32[] constant(0)
          %ini2 = (s32[], f32[4,4]{1,0}) tuple(%z2, %e)
          %ow = (s32[], f32[4,4]{1,0}) while(%ini2), condition=%outer_cond, body=%outer_body
          ROOT %r = f32[4,4]{1,0} get-tuple-element(%ow), index=1
        }
    """)
    res = analyze_hlo(nested)
    # inner dot 2*4*4*4 = 128 flops x3 inner trips x5 outer trips
    assert res["flops"] == 128 * 3 * 5


def test_fusion_called_computation_counted():
    fused = textwrap.dedent("""
        HloModule fused

        %fused_computation (fa: f32[8,8], fb: f32[8,8]) -> f32[8,8] {
          %fa = f32[8,8]{1,0} parameter(0)
          %fb = f32[8,8]{1,0} parameter(1)
          ROOT %fd = f32[8,8]{1,0} dot(%fa, %fb), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        }

        ENTRY %main (x: f32[8,8]) -> f32[8,8] {
          %x = f32[8,8]{1,0} parameter(0)
          ROOT %f = f32[8,8]{1,0} fusion(%x, %x), kind=kOutput, calls=%fused_computation
        }
    """)
    res = analyze_hlo(fused)
    assert res["flops"] == 2 * 8 * 8 * 8
