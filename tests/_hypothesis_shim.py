"""Minimal, deterministic stand-in for ``hypothesis`` (optional dev dep).

Installed into ``sys.modules`` by ``conftest.py`` ONLY when the real package
is missing, so the property tests still execute instead of crashing the whole
collection. Supports exactly the subset this suite uses:

* ``@given`` with positional strategies (mapped to the trailing test
  parameters, matching hypothesis' convention) and keyword strategies;
* ``@settings(max_examples=..., deadline=...)`` in either decorator order;
* ``st.integers(lo, hi)``, ``st.floats(lo, hi)``,
  ``st.lists(elem, min_size=..., max_size=...)``, ``st.tuples(*elems)``,
  ``st.sampled_from(elems)``.

Examples are drawn from a per-test seeded PRNG (stable across runs); the
first example of every run is the "minimal" one (lower bounds / shortest
lists) to keep a shrunk-style edge case in the mix. Install the real
``hypothesis`` (see requirements-dev.txt) for actual shrinking and coverage.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

__version__ = "0.0-shim"


class SearchStrategy:
    def __init__(self, draw_fn, minimal_fn):
        self._draw_fn = draw_fn
        self._minimal_fn = minimal_fn

    def draw(self, rng: random.Random):
        return self._draw_fn(rng)

    def minimal(self):
        return self._minimal_fn()


def _integers(min_value, max_value):
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          lambda: min_value)


def _floats(min_value, max_value, **_kw):
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value),
                          lambda: min_value)


def _lists(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        size = rng.randint(min_size, hi)
        return [elements.draw(rng) for _ in range(size)]

    return SearchStrategy(draw,
                          lambda: [elements.minimal() for _ in range(min_size)])


def _tuples(*elems):
    return SearchStrategy(lambda rng: tuple(e.draw(rng) for e in elems),
                          lambda: tuple(e.minimal() for e in elems))


def _sampled_from(elements):
    elems = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elems), lambda: elems[0])


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.lists = _lists
strategies.tuples = _tuples
strategies.sampled_from = _sampled_from
strategies.SearchStrategy = SearchStrategy

DEFAULT_MAX_EXAMPLES = 20


def settings(**kw):
    """Attach run settings; composes with @given in either order."""

    def deco(fn):
        fn._shim_settings = kw
        return fn

    return deco


class _UnsatisfiedAssumption(Exception):
    pass


def assume(condition):
    """Abort the current example when False (matches hypothesis semantics:
    the given() loop skips it instead of failing)."""
    if not condition:
        raise _UnsatisfiedAssumption
    return True


class HealthCheck:  # referenced only via settings(suppress_health_check=...)
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


def given(*pos_strats, **kw_strats):
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        # Hypothesis maps positional strategies to the RIGHTMOST parameters.
        pos_names = [p.name for p in params[-len(pos_strats):]] if pos_strats else []
        strat_map = dict(zip(pos_names, pos_strats))
        strat_map.update(kw_strats)
        outer = [p for p in params if p.name not in strat_map]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_shim_settings", None) \
                or getattr(fn, "_shim_settings", {})
            n_examples = cfg.get("max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.adler32(fn.__qualname__.encode()))
            for ex in range(n_examples):
                drawn = {name: (s.minimal() if ex == 0 else s.draw(rng))
                         for name, s in strat_map.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _UnsatisfiedAssumption:
                    continue  # assume() rejected this example; skip it

        # Hide strategy-filled params from pytest's fixture resolution.
        wrapper.__signature__ = sig.replace(parameters=outer)
        return wrapper

    return deco
