"""Kernel-specific tests for the Pallas wavefront traversal backend.

The backend-equivalence property tests in ``test_query.py`` already pin
``backend="pallas"`` against the numpy oracle on the adversarial
datasets; this file covers the shapes only the kernel layer can get
wrong — block padding (query counts that are not a multiple of the
block), dead-lane masking, the resumable chunk protocol at chunk=1, the
stats carry, and the direct ``wavefront_traverse`` contract.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bvh import build_bvh
from repro.core.query import (
    query_count,
    query_csr,
    query_csr_device,
    traverse,
    within,
)
from repro.kernels.wavefront import wavefront_traverse


def _bvh(pts):
    pts = np.asarray(pts, np.float32)
    lo = pts.min(0) - 1e-4
    hi = pts.max(0) + 1e-4
    return build_bvh(jnp.asarray(pts), jnp.asarray(lo), jnp.asarray(hi))


def _counts_oracle(pts, centers, eps):
    d2 = ((centers[:, None] - pts[None]) ** 2).sum(-1, dtype=np.float32)
    return (d2 <= np.float32(eps) ** 2).sum(1)


# --- block-shape edges -------------------------------------------------------

@pytest.mark.parametrize("q", [1, 5, 8, 127, 128, 130])
def test_query_counts_at_block_boundaries(q):
    """Query counts straddling the 128-lane block: 1 (single live lane),
    127/128/130 (one short, exact, one over — two grid steps with 126
    dead lanes). Padded lanes must never contribute."""
    rng = np.random.default_rng(q)
    pts = rng.uniform(0, 1, (60, 3)).astype(np.float32)
    bvh = _bvh(pts)
    centers = rng.uniform(0, 1, (q, 3)).astype(np.float32)
    got = np.asarray(query_count(bvh, within(jnp.asarray(centers), 0.3),
                                 backend="pallas"))
    np.testing.assert_array_equal(got, _counts_oracle(pts, centers, 0.3))


def test_minimal_tree_n2():
    """The smallest tree (one internal node, two leaves)."""
    pts = np.float32([[0.1, 0.1, 0.1], [0.9, 0.9, 0.9]])
    bvh = _bvh(pts)
    centers = np.float32([[0.1, 0.1, 0.1], [0.5, 0.5, 0.5], [2.0, 2.0, 2.0]])
    got = np.asarray(query_count(bvh, within(jnp.asarray(centers), 0.05),
                                 backend="pallas"))
    np.testing.assert_array_equal(got, [1, 0, 0])


def test_degenerate_single_leaf_geometry():
    """All points coincident — every leaf AABB is the same point, Morton
    codes fully tie. The wavefront must still count all duplicates."""
    pts = np.full((16, 3), 0.5, np.float32)
    bvh = _bvh(pts)
    centers = np.float32([[0.5, 0.5, 0.5], [0.4, 0.4, 0.4]])
    got = np.asarray(query_count(bvh, within(jnp.asarray(centers), 0.0),
                                 backend="pallas"))
    np.testing.assert_array_equal(got, [16, 0])


def test_empty_query_set():
    """q=0 short-circuits before the kernel launch; every protocol shape
    stays consistent."""
    bvh = _bvh(np.random.default_rng(0).uniform(0, 1, (32, 3)))
    pred = within(jnp.zeros((0, 3), jnp.float32), 0.1)
    assert query_count(bvh, pred, backend="pallas").shape == (0,)
    res = query_csr(bvh, pred, backend="pallas")
    assert res.indices.shape == (0,) and res.offsets.shape == (1,)


# --- engine-contract parity against the stackless reference ------------------

def test_with_stats_matches_stackless_per_query():
    """The in-kernel stats carry must reproduce the instrumented scalar
    core column-for-column (same unsorted query order => same rows)."""
    rng = np.random.default_rng(7)
    pts = rng.uniform(0, 1, (90, 3)).astype(np.float32)
    bvh = _bvh(pts)
    pred = within(jnp.asarray(rng.uniform(0, 1, (41, 3)).astype(np.float32)), 0.25)
    _, s_ref = query_count(bvh, pred, backend="stackless", with_stats=True)
    _, s_pal = query_count(bvh, pred, backend="pallas", with_stats=True)
    for field in s_ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(s_ref, field)),
            np.asarray(getattr(s_pal, field)), err_msg=field)


def test_start_nodes_matches_stackless():
    """Pair-style subtree starts (rope of each leaf) must traverse the
    identical pruned frontier on both backends."""
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 1, (50, 3)).astype(np.float32)
    bvh = _bvh(pts)
    n = bvh.num_leaves
    starts = bvh.rope[jnp.arange(n, dtype=jnp.int32) + (n - 1)]
    pred = within(jnp.asarray(pts)[bvh.leaf_perm], 0.3)
    a = query_count(bvh, pred, backend="stackless", start_nodes=starts)
    b = query_count(bvh, pred, backend="pallas", start_nodes=starts)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_csr_device_chunk1_forces_resume_rounds():
    """chunk=1 maximizes resumable rounds — every hit pauses the lane; the
    scatter-fill must still produce the exact stackless CSR."""
    rng = np.random.default_rng(5)
    pts = (rng.uniform(0, 0.05, (40, 3)) + 0.5).astype(np.float32)
    bvh = _bvh(pts)
    pred = within(jnp.asarray(pts), 0.2)
    cap = 40 * 40 + 4
    ref = query_csr_device(bvh, pred, capacity=cap, chunk=1, backend="stackless")
    got = query_csr_device(bvh, pred, capacity=cap, chunk=1, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ref.offsets), np.asarray(got.offsets))
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(got.indices))
    assert not bool(got.overflowed)


def test_stop_at_early_exit_parity():
    pts = np.full((32, 3), 0.25, np.float32)
    bvh = _bvh(pts)
    pred = within(jnp.full((6, 3), 0.25, jnp.float32), 0.1)
    a = query_count(bvh, pred, stop_at=4, backend="stackless")
    b = query_count(bvh, pred, stop_at=4, backend="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(b), 4)


# --- direct kernel contract --------------------------------------------------

def test_wavefront_traverse_direct_small_blocks():
    """Drive the kernel directly with block_q=8 so a 13-query workload
    spans two grid steps with 3 dead lanes, using a custom counting
    callback built by the factory."""
    rng = np.random.default_rng(9)
    pts = rng.uniform(0, 1, (30, 3)).astype(np.float32)
    bvh = _bvh(pts)
    centers = rng.uniform(0, 1, (13, 3)).astype(np.float32)
    eps2 = np.float32(0.3) ** 2
    qdata = (jnp.arange(13, dtype=jnp.int32), jnp.asarray(centers),
             jnp.full((13,), eps2, jnp.float32))

    def make_fns(tree):
        from repro.core.geometry import point_aabb_dist2
        n = tree.num_leaves

        def node_fn(q, carry, node):
            (_, center, r2) = q
            return point_aabb_dist2(center, tree.node_lo[node],
                                    tree.node_hi[node]) <= r2

        def leaf_fn(q, carry, obj, sorted_idx):
            (_, center, r2) = q
            leaf_node = jnp.clip(sorted_idx, 0, n - 1) + (n - 1)
            d2 = point_aabb_dist2(center, tree.node_lo[leaf_node],
                                  tree.node_hi[leaf_node])
            return carry + (d2 <= r2).astype(jnp.int32), jnp.bool_(False)

        return node_fn, leaf_fn

    got = wavefront_traverse(bvh, qdata, make_fns, jnp.int32(0), block_q=8)
    np.testing.assert_array_equal(np.asarray(got),
                                  _counts_oracle(pts, centers, 0.3))


def test_traverse_rejects_pallas_with_explanation():
    """The generic driver cannot host the kernel backend (prebuilt user
    closures can't be rebuilt inside the kernel) — the error must route
    users to the engine entry points."""
    bvh = _bvh(np.random.default_rng(0).uniform(0, 1, (8, 3)))
    qdata = (jnp.zeros((2,), jnp.int32),)
    with pytest.raises(ValueError, match="query_count"):
        traverse(bvh, qdata, lambda q, c, n: True,
                 lambda q, c, o, s: (c, False), 0, backend="pallas")


def test_jit_and_grad_safe_composition():
    """The engine call containing the pallas_call must trace under jit."""
    rng = np.random.default_rng(2)
    pts = rng.uniform(0, 1, (25, 3)).astype(np.float32)
    bvh = _bvh(pts)
    pred = within(jnp.asarray(rng.uniform(0, 1, (9, 3)).astype(np.float32)), 0.2)
    f = jax.jit(lambda b, p: query_count(b, p, backend="pallas"))
    np.testing.assert_array_equal(
        np.asarray(f(bvh, pred)),
        np.asarray(query_count(bvh, pred, backend="stackless")))
