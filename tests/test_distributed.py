"""Distributed DBSCAN (shard_map) tests — run in a subprocess so the
8-device XLA flag doesn't leak into this process."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
    import numpy as np, jax, jax.numpy as jnp
    try:  # axis_types only exists on newer JAX
        mesh = jax.make_mesh(({n},), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
    except (AttributeError, TypeError):
        mesh = jax.make_mesh(({n},), ("data",))
    import sys
    sys.path.insert(0, "{tests}")
    from conftest import make_clustered_points
    from repro.core.distributed import dbscan_distributed, slab_partition
    from repro.core.ref_numpy import dbscan_ref, core_mask_ref, labels_equivalent

    rng = np.random.default_rng({seed})
    pts = make_clustered_points(rng, {npts})
    pts_sorted, order = slab_partition(pts, {n})
    for min_pts in (2, 5):
        res = dbscan_distributed(jnp.asarray(pts_sorted), {eps}, min_pts,
                                 mesh=mesh, halo_cap=512)
        assert not bool(res.halo_overflow), "halo overflow"
        ref = dbscan_ref(pts_sorted, {eps}, min_pts)
        core = core_mask_ref(pts_sorted, {eps}, min_pts)
        assert (np.asarray(res.core_mask) == core).all(), "core mask"
        assert labels_equivalent(np.asarray(res.labels), ref, core), "labels"
    print("DIST_OK")
""")


def _run(n_dev: int, npts: int, seed: int, eps: float = 0.05) -> str:
    tests_dir = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(tests_dir), "src")
    env.pop("XLA_FLAGS", None)
    code = SCRIPT.format(n=n_dev, npts=npts, seed=seed, eps=eps,
                         tests=tests_dir)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.parametrize("n_dev", [2, 8])
def test_distributed_matches_oracle(n_dev):
    assert "DIST_OK" in _run(n_dev, 512, seed=0)


def test_distributed_cluster_spanning_all_shards():
    """A dense filament crossing every slab must merge into one cluster."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        try:  # axis_types only exists on newer JAX
            mesh = jax.make_mesh((8,), ("data",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
        except (AttributeError, TypeError):
            mesh = jax.make_mesh((8,), ("data",))
        from repro.core.distributed import dbscan_distributed
        n = 512
        x = np.linspace(0.01, 0.99, n).astype(np.float32)
        pts = np.stack([x, np.full(n, .5, np.float32),
                        np.full(n, .5, np.float32)], 1)
        res = dbscan_distributed(jnp.asarray(pts), 0.01, 2, mesh=mesh,
                                 halo_cap=64)
        labels = np.asarray(res.labels)
        assert (labels == labels[0]).all() and labels[0] >= 0, labels[:20]
        print("SPAN_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SPAN_OK" in out.stdout
