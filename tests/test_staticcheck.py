"""The invariant auditor audits itself: every rule must fire on a seeded
violation (exactly one finding), stay silent on the compliant variant,
and the CLEAN TREE must produce zero findings — plus one registered
jaxpr audit per production entry point (parametrized), and the CLI's
exit-code / JSON-report contract.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.staticcheck import (REGISTERED_AUDITS, audit_jaxpr,
                               bounded_recompiles, count_compile_signatures,
                               lint_paths, lint_source,
                               max_intermediate_elems, no_dense_intermediate,
                               no_host_transfer)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_REPRO = os.path.join(REPO, "src", "repro")


# --- AST rules: one seeded violation each, compliant twins stay silent -------

_R1_BAD = """
import jax, jax.numpy as jnp
def bad_walk(bvh, q):
    def cond(s):
        return s[0] != -1
    def body(s):
        node, acc = s
        return bvh.rope[node], acc + bvh.node_lo[node].sum()
    return jax.lax.while_loop(cond, body, (jnp.int32(0), 0.0))
"""

_R1_OK_UNION_FIND = """
import jax, jax.numpy as jnp
def union_fixpoint(parent0):
    def cond(s):
        return s[1]
    def body(s):
        p, _ = s
        p2 = jnp.minimum(p, p[p])
        return p2, jnp.any(p2 != p)
    return jax.lax.while_loop(cond, body, (parent0, jnp.bool_(True)))
"""

_R2_BAD_DECORATOR = """
import jax, functools
from jax.experimental.shard_map import shard_map
@functools.partial(jax.jit, static_argnames=("n",))
def driver(x, mesh, n):
    return shard_map(lambda a: a, mesh=mesh, in_specs=None, out_specs=None)(x)
"""

_R2_BAD_CALL = """
import jax
from jax.experimental.shard_map import shard_map
def driver(x, mesh):
    return shard_map(lambda a: a, mesh=mesh, in_specs=None, out_specs=None)(x)
run = jax.jit(driver)
"""

_R2_OK_GATED = """
from jax.experimental.shard_map import shard_map
from repro.core.distributed import _maybe_jit
@_maybe_jit
def driver(x, mesh):
    return shard_map(lambda a: a, mesh=mesh, in_specs=None, out_specs=None)(x)
"""

_R3_BAD = """
from repro.core.query import query_csr_device
def consume(bvh, pred):
    res = query_csr_device(bvh, pred, 128)
    return res.indices
"""

_R3_OK_CHECKED = """
from repro.core.query import query_csr_device
def consume(bvh, pred):
    res = query_csr_device(bvh, pred, 128)
    assert not bool(res.overflowed)
    return res.indices
"""

_R3_OK_RETURNED = """
from repro.core.query import query_csr
def passthrough(bvh, pred):
    return query_csr(bvh, pred)
"""

_R3_OK_PRAGMA = """
from repro.core.query import query_csr_device
def consume(bvh, pred):
    res = query_csr_device(bvh, pred, 128)  # staticcheck: overflow-ok
    return res.indices
"""

_R4_BAD = """
import jax.numpy as jnp
def fold(diff, L):
    return diff - jnp.round(diff / L) * L
"""

_R4_OK_GUARDED = """
import jax.numpy as jnp
def fold(diff, L):
    k = jnp.where(jnp.abs(diff) > 2 * L, 0.0, jnp.round(diff / L))
    return diff - k * L
"""

_R4_OK_NOT_MINIMAGE = """
import jax.numpy as jnp
def quantize(g, scale):
    return jnp.clip(jnp.round(g / scale), -127, 127)
"""


@pytest.mark.parametrize("rule,src", [
    ("R1-bvh-loop-outside-engine", _R1_BAD),
    ("R2-unguarded-shard-map-jit", _R2_BAD_DECORATOR),
    ("R2-unguarded-shard-map-jit", _R2_BAD_CALL),
    ("R3-unchecked-csr-overflow", _R3_BAD),
    ("R4-unguarded-minimage-fold", _R4_BAD),
])
def test_seeded_violation_fires_exactly_once(rule, src):
    findings = lint_source(textwrap.dedent(src), "fixture.py")
    assert len(findings) == 1, findings
    assert findings[0].rule == rule
    assert findings[0].line > 0


@pytest.mark.parametrize("src", [
    _R1_OK_UNION_FIND, _R2_OK_GATED, _R3_OK_CHECKED, _R3_OK_RETURNED,
    _R3_OK_PRAGMA, _R4_OK_GUARDED, _R4_OK_NOT_MINIMAGE,
])
def test_compliant_variant_is_silent(src):
    assert lint_source(textwrap.dedent(src), "fixture.py") == []


def test_engine_file_exempt_from_r1():
    findings = lint_source(textwrap.dedent(_R1_BAD), "src/repro/core/query.py")
    assert findings == []


def test_wavefront_kernel_module_exempt_from_r1():
    """kernels/wavefront.py is the blessed second home of BVH loops (the
    engine's backend='pallas' kernel body)."""
    findings = lint_source(textwrap.dedent(_R1_BAD),
                           "src/repro/kernels/wavefront.py")
    assert findings == []


def test_r1_still_fires_in_unblessed_kernels_module():
    """The allowlist is the wavefront module, not the kernels package: a
    rogue rope loop in any OTHER kernels/ file keeps the one-fire
    contract."""
    findings = lint_source(textwrap.dedent(_R1_BAD),
                           "src/repro/kernels/rogue.py")
    assert [f.rule for f in findings] == ["R1-bvh-loop-outside-engine"]


def test_generic_ignore_pragma():
    src = _R4_BAD.replace("jnp.round(diff / L) * L",
                          "jnp.round(diff / L) * L  # staticcheck: ignore")
    assert lint_source(textwrap.dedent(src), "fixture.py") == []


def test_clean_tree_has_zero_findings():
    findings, checked = lint_paths([SRC_REPRO])
    assert checked > 50            # the walk really saw the package
    assert findings == [], [str(f) for f in findings]


# --- jaxpr rules -------------------------------------------------------------

def test_no_dense_intermediate_fires_on_dense_staging():
    x = jnp.ones((64, 3))

    def dense(a):
        return ((a[:, None, :] - a[None, :, :]) ** 2).sum(-1)

    findings = audit_jaxpr(dense, (x,), [no_dense_intermediate(64 * 64)])
    assert len(findings) == 1
    assert findings[0].rule == "no-dense-intermediate"
    # and the walker is really measuring: the dense broadcast is visible
    assert max_intermediate_elems(dense, (x,)) >= 64 * 64


def test_no_dense_intermediate_silent_on_linear_fn():
    x = jnp.ones((64, 3))
    findings = audit_jaxpr(lambda a: (a * 2).sum(0), (x,),
                           [no_dense_intermediate(64 * 64), no_host_transfer()])
    assert findings == []


def test_no_host_transfer_fires_on_callback_and_device_put():
    x = jnp.ones((8,))

    def cb(a):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct(a.shape, a.dtype), a)

    f1 = audit_jaxpr(cb, (x,), [no_host_transfer()])
    assert len(f1) == 1 and "pure_callback" in f1[0].message

    f2 = audit_jaxpr(lambda a: jax.device_put(a) + 1, (x,),
                     [no_host_transfer()])
    assert len(f2) == 1 and "device_put" in f2[0].message


def test_bounded_recompiles():
    fn = lambda q: (q ** 2).sum()
    unbucketed = [(jnp.ones((n, 3)),) for n in range(1, 9)]
    bucketed = [(jnp.ones((8, 3)),)] * 8
    assert count_compile_signatures(unbucketed) == 8
    assert count_compile_signatures(bucketed) == 1
    assert len(bounded_recompiles(fn, unbucketed, 3)) == 1
    assert bounded_recompiles(fn, bucketed, 3) == []


# --- registered production audits (one test per entry point) -----------------

@pytest.mark.parametrize("audit", REGISTERED_AUDITS, ids=lambda a: a.name)
def test_registered_audit_is_clean(audit):
    assert audit.run(True) == []


# --- CLI contract ------------------------------------------------------------

def _run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-m", "repro.staticcheck", *args],
                          capture_output=True, text=True, env=env, cwd=cwd)


def test_cli_clean_tree_exits_zero(tmp_path):
    report = tmp_path / "report.json"
    out = _run_cli([SRC_REPRO, "--json", str(report)], cwd=str(tmp_path))
    assert out.returncode == 0, out.stdout + out.stderr
    data = json.loads(report.read_text())
    assert data["ok"] and data["findings"] == []
    assert data["checked_files"] > 50


def test_cli_seeded_violation_exits_nonzero_with_location(tmp_path):
    bad = tmp_path / "violation.py"
    bad.write_text(textwrap.dedent(_R4_BAD))
    report = tmp_path / "report.json"
    out = _run_cli([str(bad), "--json", str(report)], cwd=str(tmp_path))
    assert out.returncode == 1
    data = json.loads(report.read_text())
    assert not data["ok"] and len(data["findings"]) == 1
    f = data["findings"][0]
    assert f["path"] == str(bad) and f["line"] == 4
    assert f"{bad}:4" in out.stdout   # file:line in the human output too
