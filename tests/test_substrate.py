"""Substrate tests: optimizer, checkpoint store, supervisor fault tolerance,
data pipeline determinism, gradient compression."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.optim import adamw
from repro.runtime.supervisor import Supervisor, SupervisorConfig, StragglerWatchdog


# --- optimizer ---------------------------------------------------------------

def test_adamw_reduces_quadratic_loss():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0, moment_dtype="float32")
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    opt = adamw.init_opt_state(cfg, params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw.apply_updates(cfg, params, g, opt)
    assert float(loss(params)) < 0.1


def test_schedule_warmup_and_decay():
    cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(0.1, rel=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_int8_compression_error_feedback_converges():
    """Error feedback: quantization error is carried, not lost — the SUM of
    dequantized grads over steps tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1e-3, (256,)), jnp.float32)
    err = {"g": jnp.zeros((256,), jnp.float32)}
    total = jnp.zeros((256,))
    for _ in range(64):
        deq, err = adamw.compress_with_feedback({"g": g_true}, err)
        total = total + deq["g"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g_true * 64),
                               atol=2e-4)


def test_compress_roundtrip_bounded_error():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q, s = adamw.compress_int8(g)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(adamw.decompress_int8(q, s)) - np.asarray(g))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_moment_dtype_bf16():
    cfg = adamw.OptConfig(moment_dtype="bfloat16")
    opt = adamw.init_opt_state(cfg, {"w": jnp.zeros((4,), jnp.bfloat16)})
    assert opt.m["w"].dtype == jnp.bfloat16


# --- checkpoint store ---------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(tmp_path)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(3.5)}}
    store.save(7, tree)
    out, step = store.restore(jax.eval_shape(lambda: tree))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert float(out["b"]["c"]) == 3.5


def test_checkpoint_async_and_prune(tmp_path):
    store = CheckpointStore(tmp_path)
    for s in (1, 2, 3, 4):
        store.save_async(s, {"x": jnp.full((8,), s)})
    store.wait()
    store.prune(keep=2)
    assert store.steps() == [3, 4]


def test_torn_checkpoint_ignored(tmp_path):
    store = CheckpointStore(tmp_path)
    store.save(1, {"x": jnp.zeros(3)})
    torn = tmp_path / "step_00000002"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")  # no COMMIT
    assert store.latest_step() == 1


# --- supervisor fault tolerance ----------------------------------------------

def test_supervisor_restarts_after_injected_failure(tmp_path):
    store = CheckpointStore(tmp_path)
    cfg = SupervisorConfig(total_steps=20, checkpoint_every=5, max_restarts=3)
    sup = Supervisor(cfg, store)
    failed = {"done": False}

    def init_state():
        return {"w": jnp.float32(0.0), "step_sum": jnp.float32(0.0)}

    def step_fn(state, step):
        return ({"w": state["w"] + 1.0,
                 "step_sum": state["step_sum"] + step}, {"loss": state["w"]})

    def fault_hook(step):
        if step == 12 and not failed["done"]:
            failed["done"] = True
            raise RuntimeError("injected node failure")

    state = sup.run(init_state_fn=init_state, step_fn=step_fn,
                    fault_hook=fault_hook)
    assert sup.restarts == 1
    # restart resumed from step 10 (last checkpoint), so w == 20 exactly
    assert float(state["w"]) == 20.0
    assert store.latest_step() == 20


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    store = CheckpointStore(tmp_path)
    sup = Supervisor(SupervisorConfig(total_steps=5, checkpoint_every=100,
                                      max_restarts=2), store)

    def step_fn(state, step):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        sup.run(init_state_fn=lambda: {"w": jnp.float32(0)}, step_fn=step_fn)
    assert sup.restarts == 3


def test_straggler_watchdog():
    w = StragglerWatchdog(threshold=2.0, alpha=0.5)
    assert not w.observe(0, 1.0)
    assert not w.observe(1, 1.1)
    assert w.observe(2, 5.0)        # straggler
    assert w.flagged == [2]
    assert not w.observe(3, 1.0)    # ewma not poisoned by the outlier


# --- data pipeline -------------------------------------------------------------

def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=3)
    ds = SyntheticTokens(cfg)
    b1 = ds.batch_at(5)
    b2 = ds.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = ds.batch_at(6)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_data_host_sharding_partitions_global_batch():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=0)
    ds = SyntheticTokens(cfg)
    full = np.asarray(ds.batch_at(2)["tokens"])
    parts = [np.asarray(ds.batch_at(2, host_index=h, host_count=4)["tokens"])
             for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_labels_shift_by_one():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=2, seed=1)
    ds = SyntheticTokens(cfg)
    b = ds.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
