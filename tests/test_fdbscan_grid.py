"""TPU-native FDBSCAN (kernel-backed) vs oracle. Small grids — interpret
mode pays per grid step, so tests keep ncells modest."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.fdbscan_grid import (
    bin_points,
    fdbscan_grid,
    grid_dims_for,
    stencil_neighbor_map,
)
from repro.core.ref_numpy import core_mask_ref, dbscan_ref, labels_equivalent
from conftest import make_clustered_points

EPS = 0.22  # 5^3 grid over the unit box


def _run(pts, min_pts, eps=EPS, capacity=128):
    dims = grid_dims_for(np.zeros(3), np.ones(3), eps)
    return fdbscan_grid(jnp.asarray(pts), eps, min_pts,
                        scene_lo=np.zeros(3, np.float32),
                        grid_dims=dims, capacity=capacity)


@pytest.mark.parametrize("min_pts", [2, 5, 10])
def test_matches_oracle_clustered(min_pts):
    pts = make_clustered_points(np.random.default_rng(3), 300)
    res, ovf = _run(pts, min_pts)
    assert not bool(ovf)
    ref = dbscan_ref(pts, EPS, min_pts)
    core = core_mask_ref(pts, EPS, min_pts)
    np.testing.assert_array_equal(np.asarray(res.core_mask), core)
    assert labels_equivalent(np.asarray(res.labels), ref, core)


def test_matches_faithful_tier():
    """Cross-validation: TPU tier and faithful tier agree on partitions."""
    from repro.core.dbscan import fdbscan
    pts = make_clustered_points(np.random.default_rng(4), 250)
    res_g, _ = _run(pts, 5)
    res_f = fdbscan(jnp.asarray(pts), EPS, 5)
    core = np.asarray(res_f.core_mask)
    np.testing.assert_array_equal(np.asarray(res_g.core_mask), core)
    assert labels_equivalent(np.asarray(res_g.labels), np.asarray(res_f.labels), core)


def test_overflow_flag():
    pts = make_clustered_points(np.random.default_rng(5), 300)
    _, ovf = _run(pts, 2, capacity=2)
    assert bool(ovf)


def test_auto_capacity_retry():
    """Auto-tuning driver (paper §5 future work): starts at an overflowing
    capacity and doubles until the binning fits, then matches the oracle."""
    from repro.core.fdbscan_grid import fdbscan_grid_auto
    pts = make_clustered_points(np.random.default_rng(8), 250)
    res = fdbscan_grid_auto(jnp.asarray(pts), EPS, 5,
                            scene_lo=np.zeros(3, np.float32),
                            scene_hi=np.ones(3, np.float32), capacity=2)
    ref = dbscan_ref(pts, EPS, 5)
    core = core_mask_ref(pts, EPS, 5)
    np.testing.assert_array_equal(np.asarray(res.core_mask), core)
    assert labels_equivalent(np.asarray(res.labels), ref, core)


def test_points_on_cell_boundaries():
    """Points exactly on cell edges must not be double-counted or lost.

    Lattice spacing 0.1 with eps=0.15: points 0.3 and 0.6 are exact f32
    multiples of the 0.15 cell size (bin-edge cases), while no pair sits
    exactly at distance eps (0.1, 0.1414 < eps < 0.2) — exact-at-eps pairs
    are float-knife-edge and not contract-testable."""
    g = (np.arange(7) * 0.1).astype(np.float32)
    pts = np.stack(np.meshgrid(g, g, g), -1).reshape(-1, 3).astype(np.float32)
    eps = 0.15
    dims = grid_dims_for(np.zeros(3), np.full(3, 0.61), eps)
    res, ovf = fdbscan_grid(jnp.asarray(pts), eps, 2,
                            scene_lo=np.zeros(3, np.float32),
                            grid_dims=dims, capacity=32)
    assert not bool(ovf)
    ref = dbscan_ref(pts, eps, 2)
    core = core_mask_ref(pts, eps, 2)
    np.testing.assert_array_equal(np.asarray(res.core_mask), core)
    assert labels_equivalent(np.asarray(res.labels), ref, core)


def test_neighbor_map_structure():
    dims = (3, 4, 5)
    nbr = stencil_neighbor_map(dims)
    ncells = 3 * 4 * 5
    assert nbr.shape == (ncells, 27)
    # Center slot (offset 0,0,0 = index 13) is the cell itself.
    np.testing.assert_array_equal(nbr[:, 13], np.arange(ncells))
    # Corner cell has 2^3 = 8 in-bounds neighbors.
    assert (nbr[0] != ncells).sum() == 8
    # Interior cell has all 27.
    interior = np.ravel_multi_index((1, 1, 1), dims)
    assert (nbr[interior] != ncells).sum() == 27


def test_bin_points_roundtrip():
    rng = np.random.default_rng(6)
    pts = rng.uniform(0, 1, (100, 3)).astype(np.float32)
    dims = (4, 4, 4)
    bins = bin_points(jnp.asarray(pts), jnp.zeros(3, jnp.float32), 0.25, dims, 32)
    assert not bool(bins.overflowed)
    flat = np.asarray(bins.cell_pts).reshape(-1, 3)
    slots = np.asarray(bins.slot_of_point)
    np.testing.assert_allclose(flat[slots], pts, rtol=0, atol=0)
